//! Minimal host tensor (substrate): row-major f32 with shape metadata.
//!
//! This is the coordinator-side container that shuttles data between the
//! data pipeline, the PJRT runtime (as `xla::Literal`s), the native
//! attention engines, and the eval harness. It is deliberately simple —
//! heavy math happens either in compiled HLO or in the dedicated engines.

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Number of rows when viewed as a (rows, cols) matrix over the last axis.
    pub fn rows(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?} mismatched", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1);
        self.data[0]
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn mean_abs_diff(&self, other: &Tensor) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / self.data.len() as f32
    }

    pub fn cosine_sim(&self, other: &Tensor) -> f32 {
        let dot: f32 = self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum();
        let na: f32 = self.data.iter().map(|a| a * a).sum::<f32>().sqrt();
        let nb: f32 = other.data.iter().map(|b| b * b).sum::<f32>().sqrt();
        if na == 0.0 || nb == 0.0 {
            return if na == nb { 1.0 } else { 0.0 };
        }
        dot / (na * nb)
    }
}

/// `c[m,n] = a[m,k] @ b[k,n]` (f32 accumulate), the engine building block.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// `c[m,n] = a[m,k] @ b[n,k]^T`.
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!((t.rows(), t.cols()), (2, 3));
    }

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = same
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
        // against transposed form
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let bt = vec![5.0, 7.0, 6.0, 8.0]; // b^T stored row-major
        assert_eq!(matmul(&a, &b, 2, 2, 2), matmul_bt(&a, &bt, 2, 2, 2));
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.0, 2.5, 3.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!((a.cosine_sim(&a) - 1.0).abs() < 1e-6);
    }
}
