//! Real-quant NVFP4 attention engines (single head).
//!
//! Numerics contract (pinned by `rust/tests/golden/attention_golden.json`,
//! generated from the JAX oracle): identical to the fake-quant forward —
//!
//! * Q, K quantized along the head dimension (contraction of QKᵀ),
//! * V quantized along the token axis (contraction of P·V),
//! * P̃ = exp(S − rowmax) quantized per row along the key axis,
//! * all matmuls accumulate in f32 over (E2M1 code × E4M3 scale) values —
//!   exactly the FP4MM hardware semantics (§2.1).
//!
//! Since the packed-kernel refactor the hot path is
//! `packed::attend_packed_core`: inputs are quantized **once** into
//! [`PackedNvfp4`] and consumed in the packed domain via the byte-pair
//! LUT — no dequantized copies of Q/K/V exist at all. The pre-refactor
//! dequantizing implementation is kept as the dequant engine backend
//! (reachable through the deprecated [`attend_fp4_dequant`] /
//! [`attend_sage3_dequant`] shims): it is the packed-vs-dequant comparator
//! for benches and the cross-check for tests.
//!
//! Since the `AttnEngine` redesign the public entry point is
//! [`super::AttnEngine`]; the free functions here are `#[deprecated]`
//! shims kept so the golden tests pin bitwise parity across the
//! migration.

use std::borrow::Cow;

use crate::formats::block::{nvfp4_fake_quant_row, NVFP4_BLOCK};
use crate::formats::tensor4::PackedNvfp4;

use super::packed::{attend_packed_core, causal_limit, AttnScratch};

/// Attention output: `o (nq × d)` + per-row logsumexp.
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub o: Vec<f32>,
    pub lse: Vec<f32>,
    pub nq: usize,
    pub d: usize,
}

/// Pad `rows × cols` to a column count that's a multiple of 16 (zero
/// fill); borrows the input unchanged when it is already aligned.
fn pad_cols<'a>(data: &'a [f32], rows: usize, cols: usize) -> (Cow<'a, [f32]>, usize) {
    let padded = cols.div_ceil(NVFP4_BLOCK) * NVFP4_BLOCK;
    if padded == cols {
        return (Cow::Borrowed(data), cols);
    }
    let mut out = vec![0.0f32; rows * padded];
    for r in 0..rows {
        out[r * padded..r * padded + cols].copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
    (Cow::Owned(out), padded)
}

/// Transpose `rows × cols` row-major.
fn transpose(data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = data[r * cols + c];
        }
    }
    out
}

/// Quantize f32 Q/K/V into the packed layout the packed engine consumes:
/// Q/K `(n × d_pad)` blocked along `d`, V transposed `(d × nk_pad)` blocked
/// along the token axis. This is the single quantization point of the
/// engine path (everything downstream stays 4-bit).
pub fn pack_qkv_for_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
) -> (PackedNvfp4, PackedNvfp4, PackedNvfp4) {
    let (q_pad, dp) = pad_cols(q, nq, d);
    let qq = PackedNvfp4::quantize(&q_pad, nq, dp).expect("quantize q");
    let (k_pad, _) = pad_cols(k, nk, d);
    let kq = PackedNvfp4::quantize(&k_pad, nk, dp).expect("quantize k");
    let vt = transpose(v, nk, d);
    let (vt_pad, nkp) = pad_cols(&vt, d, nk);
    let vq = PackedNvfp4::quantize(&vt_pad, d, nkp).expect("quantize v");
    (qq, kq, vq)
}

/// SageAttention3 Eq. 4 preprocessing, shared by the packed and legacy
/// engines *and* the matched native backward (`qat::flash_backward_cfg`
/// must rebuild exactly the operands the forward quantized): subtract the
/// global per-column key mean and the per-tile query mean. Returns the
/// smoothed copies plus the per-tile means q̄ (`⌈nq/block_q⌉ × d`
/// row-major) needed for the high-precision ΔS fixup.
pub(crate) fn smooth_qk(
    q: &[f32],
    k: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    block_q: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut q_in = q.to_vec();
    let mut k_in = k.to_vec();
    let mut q_means = Vec::with_capacity(nq.div_ceil(block_q) * d);
    // K smoothing: subtract the global per-column key mean.
    for c in 0..d {
        let mean: f32 = (0..nk).map(|j| k[j * d + c]).sum::<f32>() / nk as f32;
        for j in 0..nk {
            k_in[j * d + c] -= mean;
        }
    }
    // Q smoothing per query tile; means kept for the high-prec ΔS.
    for i0 in (0..nq).step_by(block_q) {
        let rows = block_q.min(nq - i0);
        for c in 0..d {
            let mean: f32 = (i0..i0 + rows).map(|i| q[i * d + c]).sum::<f32>() / rows as f32;
            q_means.push(mean);
            for i in i0..i0 + rows {
                q_in[i * d + c] -= mean;
            }
        }
    }
    (q_in, k_in, q_means)
}

/// Core quantized attention with optional smoothing / two-level P — the
/// quantized-path workhorse behind `AttnEngine::forward`.
///
/// Preprocesses (smoothing per SageAttention3 Eq. 4), quantizes once into
/// packed 4-bit storage, and delegates to the packed-domain engine. The
/// non-smoothing path quantizes straight from the caller's slices — the
/// only f32 copy left is the V transpose (a layout change the packed
/// engine needs), plus zero-padding when `d` or `nk` is not 16-aligned.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_quantized(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    smooth: bool,
    two_level_p: bool,
    block_q: usize,
    scratch: &mut AttnScratch,
) -> AttnOutput {
    let (q_in, k_in, q_means): (Cow<[f32]>, Cow<[f32]>, Vec<f32>) = if smooth {
        let (qi, ki, qm) = smooth_qk(q, k, nq, nk, d, block_q);
        (Cow::Owned(qi), Cow::Owned(ki), qm)
    } else {
        (Cow::Borrowed(q), Cow::Borrowed(k), Vec::new())
    };
    let (qq, kq, vq) = pack_qkv_for_attention(&q_in, &k_in, v, nq, nk, d);
    attend_packed_core(
        &qq,
        &kq,
        &vq,
        nq,
        nk,
        d,
        causal,
        if smooth { Some(&q_means) } else { None },
        block_q,
        two_level_p,
        None,
        scratch,
    )
}

/// Training-forward core: [`attend_quantized`] plus the high-precision
/// `O′ = P·V^F / l` residual (Alg. 2 l.13). O and lse are bitwise
/// identical to the inference path under the same smoothing / two-level-P
/// knobs (the Q/K smoothing happens *before* the single quantization
/// point, so O′ rides the same smoothed P rows).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_quantized_train(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    smooth: bool,
    two_level_p: bool,
    block_q: usize,
    scratch: &mut AttnScratch,
) -> (AttnOutput, Vec<f32>) {
    let (q_in, k_in, q_means): (Cow<[f32]>, Cow<[f32]>, Vec<f32>) = if smooth {
        let (qi, ki, qm) = smooth_qk(q, k, nq, nk, d, block_q);
        (Cow::Owned(qi), Cow::Owned(ki), qm)
    } else {
        (Cow::Borrowed(q), Cow::Borrowed(k), Vec::new())
    };
    let (qq, kq, vq) = pack_qkv_for_attention(&q_in, &k_in, v, nq, nk, d);
    let mut o_prime = vec![0.0f32; nq * d];
    let out = attend_packed_core(
        &qq,
        &kq,
        &vq,
        nq,
        nk,
        d,
        causal,
        if smooth { Some(&q_means) } else { None },
        block_q,
        two_level_p,
        Some(&mut o_prime),
        scratch,
    );
    (out, o_prime)
}

/// Training-forward residuals (Alg. 2): what the QAT backward consumes.
#[derive(Clone, Debug)]
pub struct TrainOutput {
    /// Quantized-path output O (identical to [`attend_fp4`]'s).
    pub o: Vec<f32>,
    /// High-precision O′ = P·V^F / l (pre-quantization P, Alg. 2 l.13).
    pub o_prime: Vec<f32>,
    /// Per-row logsumexp L.
    pub lse: Vec<f32>,
}

/// [`attend_fp4`] plus the O′ residual — the Attn-QAT training forward.
///
/// O and lse are bitwise identical to the inference forward (same packed
/// engine, same quantization points); O′ rides along for Fix B of the
/// backward (`qat::backward`). Empty causal rows (nk < nq) produce zero
/// O and O′ with `lse = -inf`, matching the forward contract.
#[deprecated(note = "use AttnEngine::forward_train with AttnConfig::fp4()/attn_qat()")]
pub fn attend_fp4_train(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> TrainOutput {
    let mut scratch = AttnScratch::new();
    let (out, o_prime) =
        attend_quantized_train(q, k, v, nq, nk, d, causal, false, false, NVFP4_BLOCK, &mut scratch);
    TrainOutput { o: out.o, o_prime, lse: out.lse }
}

/// Quantize through real packed storage and hand back dequantized f32.
///
/// (Used by the legacy dequantizing reference below; the packed engine
/// never materialises these f32 copies.)
fn through_fp4(data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let (padded, pc) = pad_cols(data, rows, cols);
    let packed = PackedNvfp4::quantize(&padded, rows, pc).expect("quantize");
    let deq = packed.dequantize();
    if pc == cols {
        deq
    } else {
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            out[r * cols..(r + 1) * cols].copy_from_slice(&deq[r * pc..r * pc + cols]);
        }
        out
    }
}

/// Legacy dequantizing implementation (pre-packed-kernel): unpacks every
/// operand to f32 and accumulates element-wise. Identical quantization
/// lattice to the packed engine; only the f32 accumulation grouping
/// differs (per element here, per 16-block there). Kept as the
/// packed-vs-dequant comparator (`Backend::Dequant`) for benches and
/// tests.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_quantized_dequant(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    smooth: bool,
    two_level_p: bool,
    block_q: usize,
) -> AttnOutput {
    // --- preprocessing (Alg. 1 l.4 + SageAttention3 Eq. 4) ---------------
    let (q_in, k_in, q_means): (Cow<[f32]>, Cow<[f32]>, Vec<f32>) = if smooth {
        let (qi, ki, qm) = smooth_qk(q, k, nq, nk, d, block_q);
        (Cow::Owned(qi), Cow::Owned(ki), qm)
    } else {
        (Cow::Borrowed(q), Cow::Borrowed(k), Vec::new())
    };
    let qf = through_fp4(&q_in, nq, d); // blocks along d
    let kf = through_fp4(&k_in, nk, d); // blocks along d
    // V: blocks along the token axis -> quantize the transpose.
    let vt = transpose(v, nk, d);
    let vft = through_fp4(&vt, d, nk);
    let vf = transpose(&vft, d, nk);

    let scale = 1.0 / (d as f32).sqrt();
    let mut o = vec![0.0f32; nq * d];
    let mut lse = vec![0.0f32; nq];
    let mut s_row = vec![0.0f32; nk];
    let mut p_row = vec![0.0f32; nk.div_ceil(NVFP4_BLOCK) * NVFP4_BLOCK];

    for i in 0..nq {
        let qi = &qf[i * d..(i + 1) * d];
        let tile = i / block_q;
        let limit = if causal { causal_limit(i, nq, nk) } else { nk };
        if limit == 0 {
            lse[i] = f32::NEG_INFINITY;
            continue;
        }
        let mut m = f32::NEG_INFINITY;
        for j in 0..limit {
            let kj = &kf[j * d..(j + 1) * d];
            let mut acc = 0.0f32; // emulated FP4MM: f32 accumulate (l.8)
            for c in 0..d {
                acc += qi[c] * kj[c];
            }
            if smooth {
                // high-precision ΔS = q̄_tile · γ(K_j) (Eq. 5)
                let qm = &q_means[tile * d..(tile + 1) * d];
                for c in 0..d {
                    acc += qm[c] * kf[j * d + c];
                }
            }
            let s = acc * scale;
            s_row[j] = s;
            m = m.max(s);
        }
        let mut l = 0.0f32;
        for j in 0..limit {
            let p = (s_row[j] - m).exp();
            p_row[j] = p;
            l += p;
        }
        for p in p_row[limit..].iter_mut() {
            *p = 0.0;
        }
        // --- P quantization (Alg. 1 l.12 / SageAttention3 two-level) -----
        let quant_len = p_row.len();
        if two_level_p {
            let rmax = p_row[..limit].iter().fold(0.0f32, |a, &b| a.max(b));
            let factor = if rmax > 0.0 { 448.0 * 6.0 / rmax } else { 1.0 };
            for p in p_row[..quant_len].iter_mut() {
                *p *= factor;
            }
            nvfp4_fake_quant_row(&mut p_row[..quant_len]);
            for p in p_row[..quant_len].iter_mut() {
                *p /= factor;
            }
        } else {
            nvfp4_fake_quant_row(&mut p_row[..quant_len]);
        }
        // --- O = P^F · V^F / l (FP4MM #2, f32 accumulate) ------------------
        let orow = &mut o[i * d..(i + 1) * d];
        for j in 0..limit {
            let p = p_row[j];
            if p == 0.0 {
                continue;
            }
            let vj = &vf[j * d..(j + 1) * d];
            for c in 0..d {
                orow[c] += p * vj[c];
            }
        }
        let inv = 1.0 / l;
        for c in orow.iter_mut() {
            *c *= inv;
        }
        lse[i] = m + l.ln();
    }
    AttnOutput { o, lse, nq, d }
}

/// Plain NVFP4 attention (the Attn-QAT inference forward, Alg. 1).
#[deprecated(note = "use AttnEngine::forward with AttnConfig::fp4()")]
pub fn attend_fp4(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> AttnOutput {
    let mut scratch = AttnScratch::new();
    attend_quantized(q, k, v, nq, nk, d, causal, false, false, 16, &mut scratch)
}

/// SageAttention3 emulation: Q/K smoothing + two-level P quantization.
#[deprecated(note = "use AttnEngine::forward with AttnConfig::sage3()")]
pub fn attend_sage3(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> AttnOutput {
    let mut scratch = AttnScratch::new();
    attend_quantized(q, k, v, nq, nk, d, causal, true, true, 16, &mut scratch)
}

/// [`attend_sage3`] with an explicit Q-smoothing tile size (must match the
/// compiled artifact's `block_q` for bit-level comparisons, e.g. Fig. 4).
#[deprecated(note = "use AttnEngine::forward with AttnConfig::sage3().with_block_q(..)")]
#[allow(clippy::too_many_arguments)]
pub fn attend_sage3_blocked(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    block_q: usize,
) -> AttnOutput {
    let mut scratch = AttnScratch::new();
    attend_quantized(q, k, v, nq, nk, d, causal, true, true, block_q, &mut scratch)
}

/// [`attend_fp4`] via the legacy dequantizing path (bench/test comparator).
#[deprecated(note = "use AttnEngine::forward with AttnConfig::fp4().with_backend(Backend::Dequant)")]
pub fn attend_fp4_dequant(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> AttnOutput {
    attend_quantized_dequant(q, k, v, nq, nk, d, causal, false, false, 16)
}

/// [`attend_sage3`] via the legacy dequantizing path (bench/test comparator).
#[deprecated(
    note = "use AttnEngine::forward with AttnConfig::sage3().with_backend(Backend::Dequant)"
)]
pub fn attend_sage3_dequant(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> AttnOutput {
    attend_quantized_dequant(q, k, v, nq, nk, d, causal, true, true, 16)
}

#[cfg(test)]
#[allow(deprecated)] // the shims are exactly what these tests pin
mod tests {
    use super::*;
    use crate::attention::flash::attend_f32;
    use crate::rng::Rng;

    fn rand_qkv(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            rng.normal_vec(n * d, 0.0, 1.0),
            rng.normal_vec(n * d, 0.0, 1.0),
            rng.normal_vec(n * d, 0.0, 1.0),
        )
    }

    #[test]
    fn fp4_close_to_f32_but_not_equal() {
        let (n, d) = (32, 16);
        let (q, k, v) = rand_qkv(n, d, 1);
        let exact = attend_f32(&q, &k, &v, n, n, d, false);
        let quant = attend_fp4(&q, &k, &v, n, n, d, false);
        let max_diff = exact
            .o
            .iter()
            .zip(&quant.o)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff > 1e-4, "quantization should perturb: {max_diff}");
        assert!(max_diff < 0.5, "but not destroy: {max_diff}");
    }

    #[test]
    fn sage3_beats_fp4_on_outliers() {
        // Inject a large common K offset: smoothing should absorb it.
        let (n, d) = (32, 16);
        let (q, mut k, v) = rand_qkv(n, d, 2);
        for j in 0..n {
            for c in 0..d {
                k[j * d + c] += 4.0; // large shared outlier component
            }
        }
        let exact = attend_f32(&q, &k, &v, n, n, d, false);
        let e_fp4 = attend_fp4(&q, &k, &v, n, n, d, false);
        let e_sage = attend_sage3(&q, &k, &v, n, n, d, false);
        let err = |o: &AttnOutput| {
            o.o.iter()
                .zip(&exact.o)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>()
        };
        assert!(
            err(&e_sage) < err(&e_fp4),
            "sage {:.4e} fp4 {:.4e}",
            err(&e_sage),
            err(&e_fp4)
        );
    }

    #[test]
    fn causal_matches_f32_structure() {
        let (n, d) = (16, 16);
        let (q, k, v) = rand_qkv(n, d, 3);
        let out = attend_fp4(&q, &k, &v, n, n, d, true);
        // First row attends only the first key -> o ≈ fq(v0).
        let mut v0 = v[..d].to_vec();
        // v quantized along token axis: with a single attended token the
        // value still passes through the e2m1 lattice; compare loosely.
        let err: f32 = out.o[..d]
            .iter()
            .zip(&mut v0)
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.6, "err {err}");
    }

    #[test]
    fn cross_attention_shapes() {
        let (nq, nk, d) = (4, 32, 16);
        let mut rng = Rng::new(4);
        let q = rng.normal_vec(nq * d, 0.0, 1.0);
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let out = attend_fp4(&q, &k, &v, nq, nk, d, false);
        assert_eq!(out.o.len(), nq * d);
        assert_eq!(out.lse.len(), nq);
        assert!(out.o.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn non_multiple_of_16_keys() {
        // nk = 19 exercises the padding path for P and V quantization.
        let (nq, nk, d) = (3, 19, 16);
        let mut rng = Rng::new(5);
        let q = rng.normal_vec(nq * d, 0.0, 1.0);
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let out = attend_fp4(&q, &k, &v, nq, nk, d, false);
        let exact = attend_f32(&q, &k, &v, nq, nk, d, false);
        let max_diff = exact
            .o
            .iter()
            .zip(&out.o)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 0.6, "max_diff {max_diff}");
    }

    #[test]
    fn packed_and_dequant_paths_agree() {
        // Identical quantization lattice, different f32 accumulation
        // grouping: agreement to fp tolerance, not bit-exact.
        for &(nq, nk, d, seed) in &[(16usize, 16usize, 32usize, 6u64), (8, 37, 64, 7)] {
            let mut rng = Rng::new(seed);
            let q = rng.normal_vec(nq * d, 0.0, 1.0);
            let k = rng.normal_vec(nk * d, 0.0, 1.0);
            let v = rng.normal_vec(nk * d, 0.0, 1.0);
            let a = attend_fp4(&q, &k, &v, nq, nk, d, false);
            let b = attend_fp4_dequant(&q, &k, &v, nq, nk, d, false);
            let max_diff = a
                .o
                .iter()
                .zip(&b.o)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-4, "fp4 packed vs dequant: {max_diff}");
            let s = attend_sage3(&q, &k, &v, nq, nk, d, false);
            let sd = attend_sage3_dequant(&q, &k, &v, nq, nk, d, false);
            let max_diff_s = s
                .o
                .iter()
                .zip(&sd.o)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff_s < 1e-3, "sage3 packed vs dequant: {max_diff_s}");
        }
    }
}
