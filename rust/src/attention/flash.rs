//! f32 reference attention (single head) — the engine-side baseline.
//!
//! Materialises S row-by-row with a numerically-stable softmax; O(n·d)
//! memory. This is the oracle the quantized engines are compared against
//! and the high-precision fallback of the decode path.

use super::engine::AttnOutput;
use super::packed::causal_limit;

/// Single-head attention: `q (nq × d)`, `k/v (nk × d)` row-major.
///
/// Causality uses aligned ends (query i sees keys j ≤ i + nk − nq); when
/// `nk < nq` the leading queries see zero keys and produce zero output
/// with `lse = -inf` (the old unsaturated limit underflowed there).
#[deprecated(note = "use AttnEngine::forward with AttnConfig::f32()")]
pub fn attend_f32(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> AttnOutput {
    attend_f32_core(q, k, v, nq, nk, d, causal)
}

/// The f32 flash forward behind [`attend_f32`] and the engine's
/// `Precision::F32` path.
pub(crate) fn attend_f32_core(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> AttnOutput {
    let scale = 1.0 / (d as f32).sqrt();
    let mut o = vec![0.0f32; nq * d];
    let mut lse = vec![0.0f32; nq];
    let mut s_row = vec![0.0f32; nk];
    for i in 0..nq {
        let qi = &q[i * d..(i + 1) * d];
        let limit = if causal { causal_limit(i, nq, nk) } else { nk };
        if limit == 0 {
            lse[i] = f32::NEG_INFINITY;
            continue;
        }
        let mut m = f32::NEG_INFINITY;
        for j in 0..limit {
            let kj = &k[j * d..(j + 1) * d];
            let mut acc = 0.0f32;
            for c in 0..d {
                acc += qi[c] * kj[c];
            }
            let s = acc * scale;
            s_row[j] = s;
            m = m.max(s);
        }
        let mut l = 0.0f32;
        let orow = &mut o[i * d..(i + 1) * d];
        for j in 0..limit {
            let p = (s_row[j] - m).exp();
            l += p;
            let vj = &v[j * d..(j + 1) * d];
            for c in 0..d {
                orow[c] += p * vj[c];
            }
        }
        let inv = 1.0 / l;
        for c in orow.iter_mut() {
            *c *= inv;
        }
        lse[i] = m + l.ln();
    }
    AttnOutput { o, lse, nq, d }
}

#[cfg(test)]
#[allow(deprecated)] // pins the shim alongside the core
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn uniform_keys_average_values() {
        // q ⟂ all keys -> uniform attention -> output = mean(v).
        let (n, d) = (4, 8);
        let q = vec![0.0; n * d];
        let k = vec![0.0; n * d];
        let mut rng = Rng::new(1);
        let v = rng.normal_vec(n * d, 0.0, 1.0);
        let out = attend_f32(&q, &k, &v, n, n, d, false);
        for c in 0..d {
            let mean: f32 = (0..n).map(|j| v[j * d + c]).sum::<f32>() / n as f32;
            for i in 0..n {
                assert!((out.o[i * d + c] - mean).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn causal_first_row_copies_first_value() {
        let (n, d) = (3, 4);
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(n * d, 0.0, 1.0);
        let k = rng.normal_vec(n * d, 0.0, 1.0);
        let v = rng.normal_vec(n * d, 0.0, 1.0);
        let out = attend_f32(&q, &k, &v, n, n, d, true);
        for c in 0..d {
            assert!((out.o[c] - v[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_nk_less_than_nq_no_underflow() {
        // Regression: `(i + nk - nq + 1)` underflowed (debug panic /
        // release wraparound) whenever nk < nq.
        let (nq, nk, d) = (6, 2, 8);
        let mut rng = Rng::new(9);
        let q = rng.normal_vec(nq * d, 0.0, 1.0);
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let out = attend_f32(&q, &k, &v, nq, nk, d, true);
        // Queries 0..nq-nk see zero keys (aligned ends).
        for i in 0..nq - nk {
            assert!(out.o[i * d..(i + 1) * d].iter().all(|&x| x == 0.0), "row {i}");
            assert_eq!(out.lse[i], f32::NEG_INFINITY);
        }
        // The last query sees every key: must match full attention.
        let full = attend_f32(&q[(nq - 1) * d..], &k, &v, 1, nk, d, false);
        assert_eq!(&out.o[(nq - 1) * d..], &full.o[..]);
        assert_eq!(out.lse[nq - 1], full.lse[0]);
    }

    #[test]
    fn softmax_shift_invariance() {
        // Adding a constant to all scores (e.g. via k offset along a
        // direction q is constant on) must not change the output.
        let (n, d) = (5, 16);
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(n * d, 0.0, 1.0);
        let k = rng.normal_vec(n * d, 0.0, 1.0);
        let v = rng.normal_vec(n * d, 0.0, 1.0);
        let a = attend_f32(&q, &k, &v, n, n, d, false);
        let scale = 100.0f32;
        let q2: Vec<f32> = q.iter().map(|x| x * scale).collect();
        let k2: Vec<f32> = k.iter().map(|x| x / scale).collect();
        let b = attend_f32(&q2, &k2, &v, n, n, d, false);
        for (x, y) in a.o.iter().zip(&b.o) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
