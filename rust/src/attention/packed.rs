//! Packed-domain NVFP4 attention: consumes 4-bit storage directly.
//!
//! Where `engine::attend_quantized_dequant` (the legacy reference) unpacks
//! every operand back to f32 before the matmuls, this engine keeps Q, K, V
//! in [`PackedNvfp4`] form and computes QKᵀ and P·V with the byte-pair LUT
//! ([`crate::formats::lut`]): 8 table lookups + one scale multiply per
//! 16-element block, no dequant, no fresh buffers — the software analogue
//! of feeding FP4 operands straight to the tensor cores (Attn-QAT Alg. 1 /
//! SageAttention3's microscaling FP4 kernels).
//!
//! Numerics: per-block dots are *exact* (see the `lut` module docs), so the
//! only difference vs the dequantizing reference is f32 rounding in the
//! cross-block accumulation order — one add per 16-block here vs one add
//! per element there. Both sit well inside the golden-test tolerances that
//! pin the engines to the JAX oracle.
//!
//! Layout contract (the FP4MM micro-scaling convention — scales along the
//! contraction axis):
//! * `q`, `k` — `(n × d_pad)`, blocks along the head dimension,
//! * `vt` — `(d × nk_pad)`, V transposed, blocks along the token axis,
//! * P rows are quantized along the key axis on the fly (per 16 keys).
//!
//! All intermediate state lives in a caller-provided [`AttnScratch`]; after
//! warmup the engine performs zero heap allocation per call beyond the
//! `AttnOutput` it returns (the decode hot path, which cannot afford even
//! that, uses `PagedKvCache::attend_decode` writing into a caller buffer).

use crate::formats::block::NVFP4_BLOCK;
use crate::formats::e4m3;
use crate::formats::lut::{self, BLOCK_BYTES};
use crate::formats::tensor4::PackedNvfp4;

use super::engine::AttnOutput;

/// Reusable workspace for [`attend_packed`] / `attend_packed_core`.
///
/// Buffers grow to the largest (nk, d) seen and are then reused verbatim —
/// steady state performs no allocation.
#[derive(Default)]
pub struct AttnScratch {
    /// Raw scores for one query row (`nk`).
    s_row: Vec<f32>,
    /// exp(S − m) for one query row, padded to a block multiple (`nk_pad`).
    p_row: Vec<f32>,
    /// Packed E2M1 codes of the quantized P row (`nk_pad / 2`).
    p_codes: Vec<u8>,
    /// E4M3 scale bytes of the quantized P row (`nk_pad / 16`).
    p_scales: Vec<u8>,
    /// One dequantized K row (`d_pad`) for the smooth-Q ΔS precompute.
    kf_row: Vec<f32>,
    /// ΔS fixup values, `(tiles × nk)` row-major.
    delta: Vec<f32>,
    /// Dequantized Vᵀ (`d × nk_pad`) for the train-forward O′ accumulator.
    vf: Vec<f32>,
}

/// One resident row of the [`QuantQueryCache`].
struct QueryEntry {
    row: Vec<f32>,
    q4: PackedNvfp4,
    /// Tick of the last hit or fill (LRU victim = smallest).
    last_used: u64,
}

/// Bounded N-way content-keyed cache over [`lut::quantize_row_into`] —
/// the ROADMAP "quantized-query cache".
///
/// Callers that quantize an identical row repeatedly — repeated heads
/// sharing one query vector (GQA-style layouts), a decode step
/// re-attending an unchanged query, A/B reruns over the same input — pay
/// one cheap bitwise row comparison per resident entry instead of a full
/// scale+encode pass. The cache keeps up to `ways` distinct rows with LRU
/// eviction, so interleaved access patterns (two heads alternating
/// distinct queries, which thrashed the old single-entry memo to 100%
/// misses) stay resident. A lookup miss (including any NaN row, which
/// never compares equal) re-quantizes into the LRU slot, reusing its
/// buffers. Miss cost over plain `quantize_row_into` is up to `ways`
/// short-circuiting d-element compares plus a d-float copy — noise next
/// to the O(seq_len·d) page scoring each decode call performs.
pub struct QuantQueryCache {
    ways: usize,
    entries: Vec<QueryEntry>,
    tick: u64,
    /// Calls served from a resident entry.
    pub hits: u64,
    /// Calls that re-quantized.
    pub misses: u64,
}

impl QuantQueryCache {
    /// Default associativity: covers a few distinct live queries (e.g.
    /// GQA groups interleaving per head) without making misses scan far.
    pub const DEFAULT_WAYS: usize = 4;

    pub fn new() -> QuantQueryCache {
        QuantQueryCache::with_ways(QuantQueryCache::DEFAULT_WAYS)
    }

    /// Cache holding up to `ways` distinct rows (`ways ≥ 1`).
    pub fn with_ways(ways: usize) -> QuantQueryCache {
        assert!(ways >= 1, "cache needs at least one way");
        QuantQueryCache { ways, entries: Vec::new(), tick: 0, hits: 0, misses: 0 }
    }

    /// Packed NVFP4 quantization of `row` (1 × len, blocks along the row;
    /// `len` must be a multiple of 16), memoised on the exact f32 contents.
    pub fn get_or_quantize(&mut self, row: &[f32]) -> &PackedNvfp4 {
        debug_assert_eq!(row.len() % NVFP4_BLOCK, 0);
        self.tick += 1;
        let idx = match self
            .entries
            .iter()
            .position(|e| e.q4.cols == row.len() && e.row.as_slice() == row)
        {
            Some(i) => {
                self.hits += 1;
                i
            }
            None => {
                self.misses += 1;
                let i = if self.entries.len() < self.ways {
                    self.entries.push(QueryEntry {
                        row: Vec::new(),
                        q4: PackedNvfp4 {
                            rows: 1,
                            cols: 0,
                            codes: Vec::new(),
                            scales: Vec::new(),
                        },
                        last_used: 0,
                    });
                    self.entries.len() - 1
                } else {
                    // Evict the least-recently-used way, reusing its buffers.
                    let mut lru = 0;
                    for (j, e) in self.entries.iter().enumerate() {
                        if e.last_used < self.entries[lru].last_used {
                            lru = j;
                        }
                    }
                    lru
                };
                let e = &mut self.entries[i];
                lut::quantize_row_into(row, &mut e.q4.codes, &mut e.q4.scales);
                e.q4.cols = row.len();
                e.row.clear();
                e.row.extend_from_slice(row);
                i
            }
        };
        let e = &mut self.entries[idx];
        e.last_used = self.tick;
        &e.q4
    }

    /// Fraction of lookups served from a resident entry (0.0 before any
    /// lookup) — the quantity the telemetry gauge
    /// `serve.shard{i}.qcache_hit_rate` reports per shard.
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }
}

impl Default for QuantQueryCache {
    fn default() -> QuantQueryCache {
        QuantQueryCache::new()
    }
}

impl AttnScratch {
    pub fn new() -> AttnScratch {
        AttnScratch::default()
    }
}

/// Per-key ΔS fixup contributions (SageAttention3 Eq. 5): fill
/// `delta[t·nk + j] = q̄_t · kf_row[..d]` for every query tile, given one
/// dequantized smoothed key row. The forward score build here and the
/// matched backward (`qat::flash_backward_cfg`) share this function so
/// their accumulation order can never drift — the backward's bitwise
/// rebuild of the forward's S depends on it.
pub(crate) fn smooth_delta_for_key(
    q_means: &[f32],
    tiles: usize,
    d: usize,
    kf_row: &[f32],
    j: usize,
    nk: usize,
    delta: &mut [f32],
) {
    for t in 0..tiles {
        let qmt = &q_means[t * d..(t + 1) * d];
        let mut acc = 0.0f32;
        for c in 0..d {
            acc += qmt[c] * kf_row[c];
        }
        delta[t * nk + j] = acc;
    }
}

/// Aligned-ends causal limit: query `i` sees keys `j < limit`.
///
/// Saturating: when `nk < nq` the leading queries legitimately see zero
/// keys (the old `i + nk - nq + 1` underflowed there).
#[inline]
pub(crate) fn causal_limit(i: usize, nq: usize, nk: usize) -> usize {
    (i + nk + 1).saturating_sub(nq).min(nk)
}

/// Plain packed-domain NVFP4 attention (Alg. 1 on packed operands).
///
/// `q`/`k` are `(nq|nk × d_pad)` with blocks along `d`; `vt` is V
/// transposed `(d × nk_pad)` with blocks along the token axis (`nk_pad` =
/// `nk` rounded up to 16). `d` is the true head dimension (`≤ d_pad`).
#[deprecated(note = "use AttnEngine::forward_packed (the engine owns the scratch)")]
#[allow(clippy::too_many_arguments)]
pub fn attend_packed(
    q: &PackedNvfp4,
    k: &PackedNvfp4,
    vt: &PackedNvfp4,
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    scratch: &mut AttnScratch,
) -> AttnOutput {
    attend_packed_core(q, k, vt, nq, nk, d, causal, None, NVFP4_BLOCK, false, None, scratch)
}

/// Training forward (Alg. 2): [`attend_packed`] plus the high-precision
/// `O′ = P·V^F / l` residual (unquantized P, Alg. 2 l.13) the QAT backward
/// needs for Fix B. O and lse are bitwise identical to the inference path.
#[deprecated(note = "use AttnEngine::forward_train")]
#[allow(clippy::too_many_arguments)]
pub fn attend_packed_train(
    q: &PackedNvfp4,
    k: &PackedNvfp4,
    vt: &PackedNvfp4,
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    scratch: &mut AttnScratch,
) -> (AttnOutput, Vec<f32>) {
    let mut o_prime = vec![0.0f32; nq * d];
    let out = attend_packed_core(
        q,
        k,
        vt,
        nq,
        nk,
        d,
        causal,
        None,
        NVFP4_BLOCK,
        false,
        Some(&mut o_prime),
        scratch,
    );
    (out, o_prime)
}

/// Full packed engine with the SageAttention3 knobs: optional smooth-Q ΔS
/// fixup (`q_means` = per-tile means, `(⌈nq/block_q⌉ × d)` row-major) and
/// two-level P quantization. `o_prime` (training only) receives the
/// high-precision `P·V^F / l` rows.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_packed_core(
    q: &PackedNvfp4,
    k: &PackedNvfp4,
    vt: &PackedNvfp4,
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    q_means: Option<&[f32]>,
    block_q: usize,
    two_level_p: bool,
    mut o_prime: Option<&mut Vec<f32>>,
    scratch: &mut AttnScratch,
) -> AttnOutput {
    let lut = lut::pair_dot();
    let nkp = nk.div_ceil(NVFP4_BLOCK) * NVFP4_BLOCK;
    debug_assert_eq!(q.cols, k.cols, "q/k head-dim padding mismatch");
    debug_assert!(q.rows >= nq && k.rows >= nk);
    debug_assert_eq!(vt.rows, d, "vt must be (d x nk_pad)");
    debug_assert_eq!(vt.cols, nkp, "vt token padding mismatch");

    let scale = 1.0 / (d as f32).sqrt();
    let mut o = vec![0.0f32; nq * d];
    let mut lse = vec![0.0f32; nq];
    scratch.s_row.resize(nk, 0.0);
    scratch.p_row.resize(nkp, 0.0);

    // Smooth-Q ΔS fixup, precomputed per (query tile, key): q̄_t · γ(K_j)
    // in high precision (Eq. 5). K rows dequantize once each.
    let tiles = nq.div_ceil(block_q);
    if let Some(qm) = q_means {
        debug_assert_eq!(qm.len(), tiles * d, "q_means must be tiles x d");
        scratch.kf_row.resize(k.cols, 0.0);
        scratch.delta.resize(tiles * nk, 0.0);
        for j in 0..nk {
            k.dequant_row_into(j, &mut scratch.kf_row);
            smooth_delta_for_key(qm, tiles, d, &scratch.kf_row, j, nk, &mut scratch.delta);
        }
    }

    // Train forward: the O′ accumulator consumes V^F in f32 (unquantized-P
    // matmul has no packed counterpart) — dequantize Vᵀ once.
    if let Some(hp) = o_prime.as_deref_mut() {
        debug_assert_eq!(hp.len(), nq * d);
        scratch.vf.resize(d * nkp, 0.0);
        for r in 0..d {
            vt.dequant_row_into(r, &mut scratch.vf[r * nkp..(r + 1) * nkp]);
        }
    }

    let v_bpr = nkp / 2; // vt bytes per row
    let v_spb = nkp / NVFP4_BLOCK; // vt scale blocks per row

    for i in 0..nq {
        let tile = i / block_q;
        let limit = if causal { causal_limit(i, nq, nk) } else { nk };
        if limit == 0 {
            // Query precedes every key: empty softmax, defined as zeros.
            lse[i] = f32::NEG_INFINITY;
            continue;
        }
        // --- S row: packed QKᵀ (FP4MM #1, f32 accumulate) -----------------
        // One batched block-dot call per row: bitwise the per-pair dots,
        // with the query-side row setup hoisted out of the key loop.
        lut::packed_row_dots_into(lut, q, i, k, limit, &mut scratch.s_row);
        let mut m = f32::NEG_INFINITY;
        for j in 0..limit {
            let mut acc = scratch.s_row[j];
            if q_means.is_some() {
                acc += scratch.delta[tile * nk + j];
            }
            let s = acc * scale;
            scratch.s_row[j] = s;
            m = m.max(s);
        }
        let mut l = 0.0f32;
        for j in 0..limit {
            let p = (scratch.s_row[j] - m).exp();
            scratch.p_row[j] = p;
            l += p;
        }
        for p in scratch.p_row[limit..].iter_mut() {
            *p = 0.0;
        }
        // --- O′ = P · V^F / l (Alg. 2 l.13, pre-quantization P) -----------
        if let Some(hp) = o_prime.as_deref_mut() {
            let inv = 1.0 / l;
            let row = &mut hp[i * d..(i + 1) * d];
            for (c, oc) in row.iter_mut().enumerate() {
                let vrow = &scratch.vf[c * nkp..c * nkp + limit];
                let mut acc = 0.0f32;
                for (p, vv) in scratch.p_row[..limit].iter().zip(vrow) {
                    acc += p * vv;
                }
                *oc = acc * inv;
            }
        }
        // --- P quantization (Alg. 1 l.12 / SageAttention3 two-level) ------
        let mut inv_factor = 1.0f32;
        if two_level_p {
            let rmax = scratch.p_row[..limit].iter().fold(0.0f32, |a, &b| a.max(b));
            let factor = if rmax > 0.0 { 448.0 * 6.0 / rmax } else { 1.0 };
            for p in scratch.p_row.iter_mut() {
                *p *= factor;
            }
            inv_factor = 1.0 / factor;
        }
        lut::quantize_row_into(&scratch.p_row, &mut scratch.p_codes, &mut scratch.p_scales);
        // --- O = P^F · V^F / l: packed P·V (FP4MM #2) ----------------------
        let orow = &mut o[i * d..(i + 1) * d];
        for b in 0..limit.div_ceil(NVFP4_BLOCK) {
            let sp = e4m3::decode(scratch.p_scales[b]) * inv_factor;
            let p_codes = &scratch.p_codes[b * BLOCK_BYTES..(b + 1) * BLOCK_BYTES];
            for (c, oc) in orow.iter_mut().enumerate() {
                let base = c * v_bpr + b * BLOCK_BYTES;
                let dot = lut::bytes_dot(lut, p_codes, &vt.codes[base..base + BLOCK_BYTES]);
                let sv = e4m3::decode(vt.scales[c * v_spb + b]);
                *oc += dot * (sp * sv);
            }
        }
        let inv = 1.0 / l;
        for x in orow.iter_mut() {
            *x *= inv;
        }
        lse[i] = m + l.ln();
    }
    AttnOutput { o, lse, nq, d }
}

#[cfg(test)]
#[allow(deprecated)] // pins the shims against the cores they wrap
mod tests {
    use super::*;
    use crate::attention::engine::{attend_fp4, pack_qkv_for_attention};
    use crate::rng::Rng;

    #[test]
    fn attend_packed_matches_attend_fp4_bitwise() {
        // attend_fp4 quantizes once and delegates here; quantizing with the
        // same helper and calling the packed engine directly must agree
        // bit for bit.
        let (nq, nk, d) = (8, 19, 32);
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(nq * d, 0.0, 1.0);
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let (qq, kq, vq) = pack_qkv_for_attention(&q, &k, &v, nq, nk, d);
        let mut scratch = AttnScratch::new();
        let got = attend_packed(&qq, &kq, &vq, nq, nk, d, false, &mut scratch);
        let want = attend_fp4(&q, &k, &v, nq, nk, d, false);
        assert_eq!(got.o, want.o);
        assert_eq!(got.lse, want.lse);
    }

    #[test]
    fn attend_packed_matches_attend_fp4_on_outliers() {
        // Outlier-heavy inputs stress the scale path (large E4M3 scales,
        // saturating E2M1 codes); bitwise agreement must still hold, and
        // causal masking must not disturb it.
        let (nq, nk, d) = (16, 16, 16);
        let mut rng = Rng::new(21);
        let mut q = rng.normal_vec(nq * d, 0.0, 1.0);
        let mut k = rng.normal_vec(nk * d, 0.0, 1.0);
        let mut v = rng.normal_vec(nk * d, 0.0, 1.0);
        for i in (0..q.len()).step_by(7) {
            q[i] *= 50.0;
        }
        for i in (0..k.len()).step_by(5) {
            k[i] *= 200.0;
        }
        for i in (0..v.len()).step_by(3) {
            v[i] *= 100.0;
        }
        for causal in [false, true] {
            let (qq, kq, vq) = pack_qkv_for_attention(&q, &k, &v, nq, nk, d);
            let mut scratch = AttnScratch::new();
            let got = attend_packed(&qq, &kq, &vq, nq, nk, d, causal, &mut scratch);
            let want = attend_fp4(&q, &k, &v, nq, nk, d, causal);
            assert_eq!(got.o, want.o, "causal={causal}");
            assert_eq!(got.lse, want.lse, "causal={causal}");
        }
    }

    #[test]
    fn scratch_reuse_across_shapes() {
        // One scratch across growing then shrinking shapes stays correct.
        let mut scratch = AttnScratch::new();
        let mut rng = Rng::new(12);
        for &(nq, nk, d) in &[(4usize, 16usize, 16usize), (8, 64, 32), (2, 5, 16)] {
            let q = rng.normal_vec(nq * d, 0.0, 1.0);
            let k = rng.normal_vec(nk * d, 0.0, 1.0);
            let v = rng.normal_vec(nk * d, 0.0, 1.0);
            let (qq, kq, vq) = pack_qkv_for_attention(&q, &k, &v, nq, nk, d);
            let got = attend_packed(&qq, &kq, &vq, nq, nk, d, false, &mut scratch);
            let want = attend_fp4(&q, &k, &v, nq, nk, d, false);
            assert_eq!(got.o, want.o, "shape ({nq},{nk},{d})");
        }
    }

    #[test]
    fn train_forward_matches_inference_bitwise_and_adds_o_prime() {
        // The training forward must not perturb the inference output: O and
        // lse bit-identical to attend_packed, with O′ riding along. O′ uses
        // the unquantized P, so it differs from O but stays close.
        let (nq, nk, d) = (8, 19, 32);
        let mut rng = Rng::new(51);
        let q = rng.normal_vec(nq * d, 0.0, 1.0);
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let (qq, kq, vq) = pack_qkv_for_attention(&q, &k, &v, nq, nk, d);
        let mut scratch = AttnScratch::new();
        let want = attend_packed(&qq, &kq, &vq, nq, nk, d, false, &mut scratch);
        let (got, o_prime) = attend_packed_train(&qq, &kq, &vq, nq, nk, d, false, &mut scratch);
        assert_eq!(got.o, want.o);
        assert_eq!(got.lse, want.lse);
        assert_eq!(o_prime.len(), nq * d);
        let max_diff = o_prime
            .iter()
            .zip(&got.o)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff > 0.0, "O' must differ from the quantized-P O");
        assert!(max_diff < 0.5, "but stay close: {max_diff}");
    }

    #[test]
    fn train_forward_empty_causal_rows_zero_o_prime() {
        let (nq, nk, d) = (5, 3, 16);
        let mut rng = Rng::new(52);
        let q = rng.normal_vec(nq * d, 0.0, 1.0);
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let (qq, kq, vq) = pack_qkv_for_attention(&q, &k, &v, nq, nk, d);
        let mut scratch = AttnScratch::new();
        let (out, o_prime) = attend_packed_train(&qq, &kq, &vq, nq, nk, d, true, &mut scratch);
        for i in 0..2 {
            assert!(o_prime[i * d..(i + 1) * d].iter().all(|&x| x == 0.0), "row {i}");
            assert_eq!(out.lse[i], f32::NEG_INFINITY);
        }
        assert!(o_prime.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn quant_query_cache_shares_identical_rows() {
        // Repeated heads quantizing the same query row: one miss, then
        // hits, with the memoised packing bit-identical to a fresh one.
        let d = 32;
        let mut rng = Rng::new(53);
        let row_a = rng.normal_vec(d, 0.0, 1.0);
        let row_b = rng.normal_vec(d, 0.0, 1.0);
        let mut cache = QuantQueryCache::new();
        let fresh = PackedNvfp4::quantize(&row_a, 1, d).unwrap();
        {
            let q4 = cache.get_or_quantize(&row_a);
            assert_eq!(q4.codes, fresh.codes);
            assert_eq!(q4.scales, fresh.scales);
        }
        for _ in 0..3 {
            cache.get_or_quantize(&row_a);
        }
        assert_eq!((cache.hits, cache.misses), (3, 1));
        // Different content re-quantizes; switching back now *hits* (the
        // N-way cache keeps both rows resident).
        let fresh_b = PackedNvfp4::quantize(&row_b, 1, d).unwrap();
        assert_eq!(cache.get_or_quantize(&row_b).codes, fresh_b.codes);
        assert_eq!(cache.get_or_quantize(&row_a).codes, fresh.codes);
        assert_eq!((cache.hits, cache.misses), (4, 2));
    }

    #[test]
    fn quant_query_cache_does_not_thrash_on_alternating_rows() {
        // Regression: two heads with alternating distinct queries drove
        // the old single-entry memo to 100% misses. The N-way cache keeps
        // both resident — only the cold fills miss.
        let d = 32;
        let mut rng = Rng::new(54);
        let row_a = rng.normal_vec(d, 0.0, 1.0);
        let row_b = rng.normal_vec(d, 0.0, 1.0);
        let fresh_a = PackedNvfp4::quantize(&row_a, 1, d).unwrap();
        let fresh_b = PackedNvfp4::quantize(&row_b, 1, d).unwrap();
        let mut cache = QuantQueryCache::new();
        for _ in 0..5 {
            assert_eq!(cache.get_or_quantize(&row_a).codes, fresh_a.codes);
            assert_eq!(cache.get_or_quantize(&row_b).codes, fresh_b.codes);
        }
        assert_eq!((cache.hits, cache.misses), (8, 2), "alternation must not thrash");
    }

    #[test]
    fn quant_query_cache_lru_eviction_stays_correct() {
        // Three rows cycling through a 2-way cache: every access evicts
        // the LRU way (all misses), yet each packing stays bit-identical
        // to a fresh quantization — eviction reuses buffers safely.
        let d = 16;
        let mut rng = Rng::new(55);
        let rows: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(d, 0.0, 1.0)).collect();
        let fresh: Vec<PackedNvfp4> =
            rows.iter().map(|r| PackedNvfp4::quantize(r, 1, d).unwrap()).collect();
        let mut cache = QuantQueryCache::with_ways(2);
        for round in 0..3 {
            for (r, f) in rows.iter().zip(&fresh) {
                let got = cache.get_or_quantize(r);
                assert_eq!(got.codes, f.codes, "round {round}");
                assert_eq!(got.scales, f.scales, "round {round}");
            }
        }
        assert_eq!(cache.hits, 0, "round-robin over ways+1 rows always evicts");
        assert_eq!(cache.misses, 9);
        // A row of a different width joins without disturbing correctness.
        let wide = rng.normal_vec(2 * d, 0.0, 1.0);
        let fresh_wide = PackedNvfp4::quantize(&wide, 1, 2 * d).unwrap();
        assert_eq!(cache.get_or_quantize(&wide).codes, fresh_wide.codes);
    }

    #[test]
    fn causal_nq_gt_nk_has_empty_rows() {
        // Regression: the old causal limit underflowed when nk < nq.
        let (nq, nk, d) = (5, 3, 16);
        let mut rng = Rng::new(13);
        let q = rng.normal_vec(nq * d, 0.0, 1.0);
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let out = attend_fp4(&q, &k, &v, nq, nk, d, true);
        // Queries 0 and 1 precede every key (aligned ends): zero output.
        for i in 0..2 {
            assert!(out.o[i * d..(i + 1) * d].iter().all(|&x| x == 0.0), "row {i}");
            assert_eq!(out.lse[i], f32::NEG_INFINITY);
        }
        // Later rows are ordinary finite attention outputs.
        for i in 2..nq {
            assert!(out.o[i * d..(i + 1) * d].iter().all(|x| x.is_finite()), "row {i}");
            assert!(out.lse[i].is_finite());
        }
    }
}
