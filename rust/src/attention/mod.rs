//! Native Rust attention — one engine API over the "real quant" kernels.
//!
//! Where the JAX/Pallas layers *fake-quantize* (Eq. 6), these engines run
//! attention on **actually packed** NVFP4 tensors (4-bit codes + E4M3
//! scales), consuming them through the byte-pair LUT exactly like
//! Blackwell's FP4MM. The public surface is the session API in [`api`]:
//!
//! * [`AttnConfig`] — precision family (`f32` / `fp4` / `sage3`), causal
//!   flag, smoothing, two-level P, Q-tile size, packed-vs-dequant backend,
//!   and the backward ablation switches, with one [`AttnConfig::parse`]
//!   vocabulary covering every variant name the crate ever accepted;
//! * [`AttnEngine`] — owns its workspaces and exposes
//!   [`forward`](AttnEngine::forward) /
//!   [`forward_train`](AttnEngine::forward_train) over multi-head
//!   `(h, n, d)` views (heads fanned out with `std::thread::scope`),
//!   [`forward_packed`](AttnEngine::forward_packed) for pre-quantized
//!   operands, and [`decode`](AttnEngine::decode) /
//!   [`prefill`](AttnEngine::prefill) over the paged FP4 KV cache.
//!
//! Uses: Figure 4 (fake-quant HLO vs this real-quant engine), the serving
//! decode path (`kvcache` / `serve`), and the native QAT trainer (`qat`).
//!
//! ## Migrating from the free functions
//!
//! The pre-engine free functions remain as thin `#[deprecated]` shims so
//! the golden tests pin bitwise parity; new code should build an engine:
//!
//! | old free function | engine equivalent |
//! |-------------------|-------------------|
//! | `attend_f32(q,k,v,nq,nk,d,causal)` | `AttnEngine::new(AttnConfig::f32().with_causal(causal)).forward(q,k,v,1,nq,nk,d)` |
//! | `attend_fp4(...)` | config `AttnConfig::fp4()` |
//! | `attend_sage3(...)` | config `AttnConfig::sage3()` |
//! | `attend_sage3_blocked(..., block_q)` | config `AttnConfig::sage3().with_block_q(block_q)` |
//! | `attend_fp4_dequant` / `attend_sage3_dequant` | config `.with_backend(Backend::Dequant)` |
//! | `attend_fp4_train(...)` | [`AttnEngine::forward_train`] (config `AttnConfig::fp4()` or [`AttnConfig::attn_qat`]) |
//! | `attend_packed` / `attend_packed_train` | [`AttnEngine::forward_packed`] / [`AttnEngine::forward_train`] |
//! | `attend(..., Variant::X)` | `AttnEngine::new(AttnConfig::parse("x")?)` |
//! | `PagedKvCache::attend_decode` per head | [`AttnEngine::decode`] (all heads of a layer; `AttnConfig::f32()` = the gather baseline) |
//! | token-at-a-time prompt ingestion | [`AttnEngine::prefill`] (batched multi-query causal) |

pub mod api;
pub mod engine;
pub mod flash;
pub mod packed;

pub use api::{
    AttnBatch, AttnConfig, AttnEngine, Backend, BwdSwitches, ParseVariantError, Precision,
    TrainBatch,
};
#[allow(deprecated)]
pub use engine::{attend_fp4, attend_fp4_train, attend_sage3};
pub use engine::{AttnOutput, TrainOutput};
#[allow(deprecated)]
pub use flash::attend_f32;
#[allow(deprecated)]
pub use packed::{attend_packed, attend_packed_train};
pub use packed::{AttnScratch, QuantQueryCache};

/// Legacy forward-variant selector.
///
/// Superseded by [`AttnConfig`], which carries the same three precision
/// families plus every other knob in one place.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    F32,
    Fp4,
    Sage3,
}

impl Variant {
    #[deprecated(note = "use AttnConfig::parse — one vocabulary, errors list the valid names")]
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "f32" | "bf16" => Some(Variant::F32),
            "fp4" | "qat" => Some(Variant::Fp4),
            "sage3" => Some(Variant::Sage3),
            _ => None,
        }
    }
}

/// Dispatch an (n × d) single-head attention over the chosen variant.
#[deprecated(note = "build an AttnEngine from an AttnConfig and call forward")]
pub fn attend(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    causal: bool,
    variant: Variant,
) -> AttnOutput {
    let mut scratch = AttnScratch::new();
    match variant {
        Variant::F32 => flash::attend_f32_core(q, k, v, n, n, d, causal),
        Variant::Fp4 => {
            engine::attend_quantized(q, k, v, n, n, d, causal, false, false, 16, &mut scratch)
        }
        Variant::Sage3 => {
            engine::attend_quantized(q, k, v, n, n, d, causal, true, true, 16, &mut scratch)
        }
    }
}
