//! Native Rust attention engines — the "real quant" side of the system.
//!
//! Where the JAX/Pallas layers *fake-quantize* (Eq. 6), these engines run
//! attention on **actually packed** NVFP4 tensors (4-bit codes + E4M3
//! scales), dequantizing block-wise into the f32 accumulator exactly like
//! Blackwell's FP4MM. Uses:
//!
//! * Figure 4 — fake-quant (compiled HLO) vs real-quant (this module)
//!   agreement on identical inputs;
//! * the serving decode path — attention over the FP4 paged KV cache
//!   (`kvcache`), where the per-token query is f32 and K/V live in NVFP4;
//! * a reference f32 flash implementation for baseline comparisons.
//!
//! Variants mirror `python/compile/kernels/ref.PRESETS` forward semantics:
//! `F32`, `Fp4` (plain NVFP4, the Attn-QAT inference kernel), `Sage3`
//! (K/Q smoothing + two-level P quantization).

pub mod engine;
pub mod flash;
pub mod packed;

pub use engine::{attend_fp4, attend_fp4_train, attend_sage3, AttnOutput, TrainOutput};
pub use flash::attend_f32;
pub use packed::{attend_packed, attend_packed_train, AttnScratch, QuantQueryCache};

/// Forward-variant selector for the native engines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    F32,
    Fp4,
    Sage3,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "f32" | "bf16" => Some(Variant::F32),
            "fp4" | "qat" => Some(Variant::Fp4),
            "sage3" => Some(Variant::Sage3),
            _ => None,
        }
    }
}

/// Dispatch an (n × d) single-head attention over the chosen variant.
pub fn attend(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    causal: bool,
    variant: Variant,
) -> AttnOutput {
    match variant {
        Variant::F32 => attend_f32(q, k, v, n, n, d, causal),
        Variant::Fp4 => attend_fp4(q, k, v, n, n, d, causal),
        Variant::Sage3 => attend_sage3(q, k, v, n, n, d, causal),
    }
}
