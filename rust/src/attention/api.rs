//! The unified attention API: [`AttnConfig`] + [`AttnEngine`].
//!
//! One config describes *what* to compute — precision family, causal
//! masking, SageAttention3 smoothing / two-level P, the Q-smoothing tile
//! size, the packed-vs-dequant backend, and the backward ablation switches
//! — and one engine object *owns* everything needed to compute it: the
//! per-head [`AttnScratch`] workspaces and the paged-decode
//! [`DecodeScratch`] (with its N-way quantized-query cache). This replaces
//! the free-function zoo (`attend_f32`, `attend_fp4`, `attend_sage3`, …)
//! with a session API over **multi-head** `(h, n, d)` views:
//!
//! ```no_run
//! use attn_qat::attention::{AttnConfig, AttnEngine};
//!
//! let mut engine = AttnEngine::new(AttnConfig::parse("sage3").unwrap().with_causal(true));
//! # let (heads, n, d) = (4usize, 128usize, 64usize);
//! # let q = vec![0.0f32; heads * n * d];
//! # let (k, v) = (q.clone(), q.clone());
//! let out = engine.forward(&q, &k, &v, heads, n, n, d); // (h × n × d) + lse
//! ```
//!
//! Heads are independent single-head problems; `forward` / `forward_train`
//! fan them out with `std::thread::scope`, one workspace per lane, and the
//! per-head results are **bitwise identical** to `h` independent
//! single-head calls (pinned by `rust/tests/engine_api.rs`). `decode` and
//! `prefill` run against the paged FP4 KV cache and double as the serving
//! backends of `serve::DecodeServer` — an `AttnConfig::f32()` engine *is*
//! the gather + f32 A/B baseline, no separate switch needed.

use anyhow::{ensure, Result};

use crate::formats::tensor4::PackedNvfp4;
use crate::json::Json;
use crate::kvcache::{DecodeScratch, PagedKvCache, SeqSlot};

use super::engine::{
    attend_quantized, attend_quantized_dequant, attend_quantized_train, AttnOutput,
};
use super::flash::attend_f32_core;
use super::packed::{attend_packed_core, AttnScratch};

/// Forward precision family (the `python/compile/kernels/ref.PRESETS`
/// forward semantics, unified across the old `Variant` / `QatVariant`
/// selectors).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Reference f32 flash attention (also serves the "bf16" label: this
    /// crate emulates the paper's BF16 baseline in f32).
    F32,
    /// Plain NVFP4 — the Attn-QAT inference kernel (Alg. 1).
    Fp4,
    /// SageAttention3 emulation: Q/K smoothing + two-level P quantization.
    Sage3,
}

/// Quantized-path compute backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Packed-domain byte-pair LUT kernels (the default hot path).
    Packed,
    /// Legacy dequantizing reference — same quantization lattice, per
    /// element f32 accumulation. Kept as the packed-vs-dequant comparator
    /// for benches and tests.
    Dequant,
}

/// Backward ablation switches (the paper's §3.2 fixes; see the `qat`
/// module docs for the switch-combination → Figure-3-curve table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BwdSwitches {
    /// Fix A (part 1): recompute S from the packed FP4 Q̂/K̂ and run the
    /// dV/dQ/dK matmuls over the dequantized Q^F/K^F/V^F.
    pub fq_inputs: bool,
    /// Fix A (part 2): fake-quantize the recomputed P before dV (l.11).
    pub fq_p: bool,
    /// Fix B: D = rowsum(dO ∘ O′) instead of rowsum(dO ∘ O) (l.3).
    pub high_prec_o: bool,
}

impl BwdSwitches {
    /// Both fixes on — the matched Attn-QAT backward.
    pub const MATCHED: BwdSwitches =
        BwdSwitches { fq_inputs: true, fq_p: true, high_prec_o: true };
    /// Stock f32 FA backward (the "drop-in" / f32-baseline setting).
    pub const STOCK: BwdSwitches =
        BwdSwitches { fq_inputs: false, fq_p: false, high_prec_o: false };
}

/// Error from [`AttnConfig::parse`]: names every accepted variant instead
/// of silently returning `None`.
#[derive(Clone, Debug)]
pub struct ParseVariantError {
    got: String,
}

impl std::fmt::Display for ParseVariantError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown attention variant '{}' (expected one of: {})",
            self.got,
            AttnConfig::VARIANT_NAMES.join(", ")
        )
    }
}

impl std::error::Error for ParseVariantError {}

/// Everything the attention engines are configurable on, in one place.
///
/// Presets ([`AttnConfig::f32`], [`AttnConfig::fp4`], [`AttnConfig::sage3`],
/// [`AttnConfig::attn_qat`]) pin the exact semantics the old free
/// functions had; builder methods refine them. `smooth` / `two_level_p`
/// are independent knobs (e.g. the paper's `qat_smoothk` ablation is
/// `fp4()` + smoothing), `bwd` only matters to training sessions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnConfig {
    /// Forward precision family.
    pub precision: Precision,
    /// Aligned-ends causal masking (query i sees keys j ≤ i + nk − nq).
    pub causal: bool,
    /// SageAttention3 Eq. 4 smoothing (per-column K mean, per-tile Q mean
    /// with a high-precision ΔS fixup). Quantized precisions only.
    pub smooth: bool,
    /// Two-level P quantization (per-row rescale into the E4M3 range
    /// before the NVFP4 pass). Quantized precisions only.
    pub two_level_p: bool,
    /// Q-smoothing tile size; must match a compiled artifact's tile for
    /// bit-level comparisons (e.g. Fig. 4 uses 64).
    pub block_q: usize,
    /// Packed-LUT hot path or the legacy dequantizing comparator.
    pub backend: Backend,
    /// Backward ablation switches consumed by `qat::flash_backward`.
    pub bwd: BwdSwitches,
}

impl AttnConfig {
    /// Every name [`AttnConfig::parse`] accepts, in display order.
    pub const VARIANT_NAMES: [&'static str; 10] = [
        "f32",
        "bf16",
        "fp4",
        "dropin",
        "qat",
        "attn_qat",
        "qat_no_o_prime",
        "qat_no_fq_p",
        "qat_smoothk",
        "sage3",
    ];

    /// Reference f32 engine (the paper's BF16 baseline), stock backward.
    pub fn f32() -> AttnConfig {
        AttnConfig {
            precision: Precision::F32,
            causal: false,
            smooth: false,
            two_level_p: false,
            block_q: 16,
            backend: Backend::Packed,
            bwd: BwdSwitches::STOCK,
        }
    }

    /// Plain NVFP4 forward with the stock backward — quantized inference,
    /// or the unstable "drop-in" QAT when trained.
    pub fn fp4() -> AttnConfig {
        AttnConfig { precision: Precision::Fp4, ..AttnConfig::f32() }
    }

    /// NVFP4 forward + the matched backward (both §3.2 fixes): the
    /// Attn-QAT training configuration.
    pub fn attn_qat() -> AttnConfig {
        AttnConfig { bwd: BwdSwitches::MATCHED, ..AttnConfig::fp4() }
    }

    /// The paper's smooth-K QAT ablation: the matched Attn-QAT backward
    /// with SageAttention3 Eq. 4 smoothing on the training forward. The
    /// backward recomputes through the smoothed operands, so the matched
    /// property holds (pinned by the model-level parity test in
    /// `model::qat_model`). Training-only: the paged serving path rejects
    /// smoothing, so serve exported weights with [`AttnConfig::fp4`].
    pub fn qat_smoothk() -> AttnConfig {
        AttnConfig::attn_qat().with_smooth(true)
    }

    /// SageAttention3 emulation: smoothing + two-level P.
    pub fn sage3() -> AttnConfig {
        AttnConfig {
            precision: Precision::Sage3,
            smooth: true,
            two_level_p: true,
            ..AttnConfig::fp4()
        }
    }

    /// One vocabulary for every engine — replaces `Variant::parse` and
    /// `QatVariant::parse`. Forward semantics and backward switches land
    /// in the same config:
    ///
    /// | name | forward | backward |
    /// |------|---------|----------|
    /// | `f32`, `bf16` | f32 (bf16 **aliases the f32 engine**: the BF16 baseline is emulated in f32) | stock |
    /// | `fp4`, `dropin` | NVFP4 | stock (the unstable drop-in QAT) |
    /// | `qat`, `attn_qat` | NVFP4 | matched (both fixes) |
    /// | `qat_no_o_prime` | NVFP4 | matched − Fix B |
    /// | `qat_no_fq_p` | NVFP4 | matched − Fix A's P quantization |
    /// | `qat_smoothk` | NVFP4 + K/Q smoothing | matched (recomputes through the smoothed operands) |
    /// | `sage3` | NVFP4 + smoothing + two-level P | stock (no native smooth backward yet) |
    ///
    /// Every name returns its preset verbatim, so parsing a name and
    /// spelling the preset in code can never disagree. Unknown names
    /// produce a [`ParseVariantError`] listing the accepted vocabulary
    /// rather than a silent `None`.
    pub fn parse(s: &str) -> Result<AttnConfig, ParseVariantError> {
        match s {
            "f32" | "bf16" => Ok(AttnConfig::f32()),
            "fp4" | "dropin" => Ok(AttnConfig::fp4()),
            "qat" | "attn_qat" => Ok(AttnConfig::attn_qat()),
            "qat_no_o_prime" => Ok(AttnConfig::attn_qat()
                .with_bwd(BwdSwitches { high_prec_o: false, ..BwdSwitches::MATCHED })),
            "qat_no_fq_p" => Ok(AttnConfig::attn_qat()
                .with_bwd(BwdSwitches { fq_p: false, ..BwdSwitches::MATCHED })),
            "qat_smoothk" => Ok(AttnConfig::qat_smoothk()),
            "sage3" => Ok(AttnConfig::sage3()),
            _ => Err(ParseVariantError { got: s.to_string() }),
        }
    }

    /// Set causal masking.
    pub fn with_causal(mut self, causal: bool) -> AttnConfig {
        self.causal = causal;
        self
    }

    /// Set the Q-smoothing tile size.
    pub fn with_block_q(mut self, block_q: usize) -> AttnConfig {
        assert!(block_q > 0, "block_q must be positive");
        self.block_q = block_q;
        self
    }

    /// Select the compute backend.
    pub fn with_backend(mut self, backend: Backend) -> AttnConfig {
        self.backend = backend;
        self
    }

    /// Set the backward ablation switches.
    pub fn with_bwd(mut self, bwd: BwdSwitches) -> AttnConfig {
        self.bwd = bwd;
        self
    }

    /// Toggle SageAttention3 Eq. 4 smoothing (quantized precisions only).
    /// The matched native backward (`qat::flash_backward_cfg`) rebuilds
    /// the smoothed operands, so e.g. the paper's smooth-K QAT ablation is
    /// `AttnConfig::attn_qat().with_smooth(true)`.
    pub fn with_smooth(mut self, smooth: bool) -> AttnConfig {
        self.smooth = smooth;
        self
    }

    /// Toggle two-level P quantization (per-row rescale into the E4M3
    /// range before the NVFP4 pass; quantized precisions only).
    pub fn with_two_level_p(mut self, two_level_p: bool) -> AttnConfig {
        self.two_level_p = two_level_p;
        self
    }

    /// Does the forward run through a quantized engine?
    pub fn quantized(&self) -> bool {
        self.precision != Precision::F32
    }

    /// The [`AttnConfig::parse`] name this config round-trips to, ignoring
    /// the knobs no preset pins (`causal`, `block_q`, `backend`);
    /// `"custom"` when no preset matches. Aliased presets report their
    /// first name in [`AttnConfig::VARIANT_NAMES`] (`f32`, not `bf16`).
    pub fn variant_name(&self) -> &'static str {
        let normalized =
            AttnConfig { causal: false, block_q: 16, backend: Backend::Packed, ..*self };
        for name in AttnConfig::VARIANT_NAMES {
            if AttnConfig::parse(name).expect("known variant name") == normalized {
                return name;
            }
        }
        "custom"
    }

    /// Reflect every field (plus the resolved variant name) for the
    /// telemetry snapshot's `config` section.
    pub fn to_json(&self) -> Json {
        let precision = match self.precision {
            Precision::F32 => "f32",
            Precision::Fp4 => "fp4",
            Precision::Sage3 => "sage3",
        };
        let backend = match self.backend {
            Backend::Packed => "packed",
            Backend::Dequant => "dequant",
        };
        Json::obj(vec![
            ("variant", Json::Str(self.variant_name().to_string())),
            ("precision", Json::Str(precision.to_string())),
            ("causal", Json::Bool(self.causal)),
            ("smooth", Json::Bool(self.smooth)),
            ("two_level_p", Json::Bool(self.two_level_p)),
            ("block_q", Json::Num(self.block_q as f64)),
            ("backend", Json::Str(backend.to_string())),
            (
                "bwd",
                Json::obj(vec![
                    ("fq_inputs", Json::Bool(self.bwd.fq_inputs)),
                    ("fq_p", Json::Bool(self.bwd.fq_p)),
                    ("high_prec_o", Json::Bool(self.bwd.high_prec_o)),
                ]),
            ),
        ])
    }
}

impl Default for AttnConfig {
    fn default() -> AttnConfig {
        AttnConfig::fp4()
    }
}

/// Multi-head attention output: `o` is `(heads × nq × d)` row-major,
/// `lse` is `(heads × nq)`.
#[derive(Clone, Debug)]
pub struct AttnBatch {
    pub o: Vec<f32>,
    pub lse: Vec<f32>,
    pub heads: usize,
    pub nq: usize,
    pub d: usize,
}

impl AttnBatch {
    /// Output rows of head `h` (`nq × d`).
    pub fn head_o(&self, h: usize) -> &[f32] {
        &self.o[h * self.nq * self.d..(h + 1) * self.nq * self.d]
    }

    /// Logsumexp rows of head `h` (`nq`).
    pub fn head_lse(&self, h: usize) -> &[f32] {
        &self.lse[h * self.nq..(h + 1) * self.nq]
    }
}

/// Multi-head training-forward output: [`AttnBatch`] fields plus the
/// high-precision `O′ = P·V^F / l` residual (Alg. 2 l.13) per head.
#[derive(Clone, Debug)]
pub struct TrainBatch {
    /// Quantized-path output O, bitwise identical to [`AttnEngine::forward`].
    pub o: Vec<f32>,
    /// High-precision O′ (pre-quantization P); equals `o` for f32 sessions.
    pub o_prime: Vec<f32>,
    /// Per-row logsumexp L, `(heads × nq)`.
    pub lse: Vec<f32>,
    pub heads: usize,
    pub nq: usize,
    pub d: usize,
}

/// One attention session: a config plus the owned workspaces to run it.
///
/// Construction is cheap (buffers grow lazily and are then reused
/// verbatim); steady state performs no allocation beyond the outputs.
/// The engine is `Send`, so sessions can be moved into worker threads —
/// `serve::DecodeServer` keeps one per batch slot.
pub struct AttnEngine {
    cfg: AttnConfig,
    /// One workspace per head fan-out lane.
    scratches: Vec<AttnScratch>,
    /// Paged-decode workspace (quantized-query cache, page buffers).
    decode_scratch: DecodeScratch,
}

impl AttnEngine {
    pub fn new(cfg: AttnConfig) -> AttnEngine {
        AttnEngine { cfg, scratches: Vec::new(), decode_scratch: DecodeScratch::new() }
    }

    pub fn config(&self) -> &AttnConfig {
        &self.cfg
    }

    /// (hits, misses) of the paged-decode quantized-query cache.
    pub fn query_cache_stats(&self) -> (u64, u64) {
        self.decode_scratch.query_cache_stats()
    }

    fn grow_scratches(&mut self, heads: usize) {
        while self.scratches.len() < heads {
            self.scratches.push(AttnScratch::new());
        }
    }

    /// The paged KV backends implement exactly two kernels — fused packed
    /// fp4 and the gather + f32 baseline. Reject quantized configs whose
    /// knobs name a kernel the paged path cannot honor, instead of
    /// silently computing something the config does not describe.
    fn ensure_paged_config(&self, what: &str) -> Result<()> {
        if self.cfg.quantized() {
            ensure!(
                self.cfg.backend == Backend::Packed
                    && !self.cfg.smooth
                    && !self.cfg.two_level_p,
                "{what} supports only the packed fp4 and f32 configs \
                 (smoothing / two-level P / the dequant backend have no paged path)"
            );
        }
        Ok(())
    }

    /// Multi-head forward over `(heads × n × d)` row-major views:
    /// `q` is `(heads × nq × d)`, `k`/`v` are `(heads × nk × d)`.
    ///
    /// Heads run as independent single-head problems — fanned out across
    /// threads when `heads > 1` — and each head's `o`/`lse` is bitwise
    /// identical to a single-head call with the same config.
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        heads: usize,
        nq: usize,
        nk: usize,
        d: usize,
    ) -> AttnBatch {
        assert_eq!(q.len(), heads * nq * d, "q must be (heads x nq x d)");
        assert_eq!(k.len(), heads * nk * d, "k must be (heads x nk x d)");
        assert_eq!(v.len(), heads * nk * d, "v must be (heads x nk x d)");
        self.grow_scratches(heads.max(1));
        let cfg = self.cfg;
        let mut o = vec![0.0f32; heads * nq * d];
        let mut lse = vec![0.0f32; heads * nq];
        if heads == 1 {
            let out = run_head(&cfg, q, k, v, nq, nk, d, &mut self.scratches[0]);
            o.copy_from_slice(&out.o);
            lse.copy_from_slice(&out.lse);
        } else if heads > 1 {
            let scratches = &mut self.scratches;
            std::thread::scope(|scope| {
                for (h, ((oh, lh), scratch)) in o
                    .chunks_mut(nq * d)
                    .zip(lse.chunks_mut(nq))
                    .zip(scratches.iter_mut())
                    .enumerate()
                {
                    let qh = &q[h * nq * d..(h + 1) * nq * d];
                    let kh = &k[h * nk * d..(h + 1) * nk * d];
                    let vh = &v[h * nk * d..(h + 1) * nk * d];
                    scope.spawn(move || {
                        let out = run_head(&cfg, qh, kh, vh, nq, nk, d, scratch);
                        oh.copy_from_slice(&out.o);
                        lh.copy_from_slice(&out.lse);
                    });
                }
            });
        }
        AttnBatch { o, lse, heads, nq, d }
    }

    /// Multi-head training forward: [`AttnEngine::forward`] plus the O′
    /// residual the QAT backward consumes (Fix B). O and lse stay bitwise
    /// identical to the inference forward — including under smoothing and
    /// two-level P, whose recompute terms `qat::flash_backward_cfg`
    /// mirrors; for f32 sessions `o_prime == o`.
    ///
    /// The dequant comparator backend has no training path — training
    /// sessions must use the packed backend.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_train(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        heads: usize,
        nq: usize,
        nk: usize,
        d: usize,
    ) -> TrainBatch {
        assert_eq!(q.len(), heads * nq * d, "q must be (heads x nq x d)");
        assert_eq!(k.len(), heads * nk * d, "k must be (heads x nk x d)");
        assert_eq!(v.len(), heads * nk * d, "v must be (heads x nk x d)");
        assert!(
            self.cfg.backend == Backend::Packed,
            "training forward runs the packed engine only (no dequant comparator path)"
        );
        self.grow_scratches(heads.max(1));
        let cfg = self.cfg;
        let mut o = vec![0.0f32; heads * nq * d];
        let mut o_prime = vec![0.0f32; heads * nq * d];
        let mut lse = vec![0.0f32; heads * nq];
        if heads == 1 {
            let (out, op) = run_head_train(&cfg, q, k, v, nq, nk, d, &mut self.scratches[0]);
            o.copy_from_slice(&out.o);
            o_prime.copy_from_slice(&op);
            lse.copy_from_slice(&out.lse);
        } else if heads > 1 {
            let scratches = &mut self.scratches;
            std::thread::scope(|scope| {
                for (h, (((oh, oph), lh), scratch)) in o
                    .chunks_mut(nq * d)
                    .zip(o_prime.chunks_mut(nq * d))
                    .zip(lse.chunks_mut(nq))
                    .zip(scratches.iter_mut())
                    .enumerate()
                {
                    let qh = &q[h * nq * d..(h + 1) * nq * d];
                    let kh = &k[h * nk * d..(h + 1) * nk * d];
                    let vh = &v[h * nk * d..(h + 1) * nk * d];
                    scope.spawn(move || {
                        let (out, op) = run_head_train(&cfg, qh, kh, vh, nq, nk, d, scratch);
                        oh.copy_from_slice(&out.o);
                        oph.copy_from_slice(&op);
                        lh.copy_from_slice(&out.lse);
                    });
                }
            });
        }
        TrainBatch { o, o_prime, lse, heads, nq, d }
    }

    /// Single-head forward over **pre-quantized** operands — the
    /// steady-state kernel cost a resident packed KV cache would see
    /// (quantization hoisted out, workspace reused). `q`/`k` are
    /// `(n × d_pad)` with blocks along `d`; `vt` is V transposed
    /// `(d × nk_pad)` with blocks along the token axis.
    ///
    /// Smoothing is a pre-quantization transform and cannot apply here;
    /// the config's `two_level_p` and `causal` are honored.
    pub fn forward_packed(
        &mut self,
        q: &PackedNvfp4,
        k: &PackedNvfp4,
        vt: &PackedNvfp4,
        nq: usize,
        nk: usize,
        d: usize,
    ) -> AttnOutput {
        assert!(!self.cfg.smooth, "forward_packed cannot smooth pre-quantized operands");
        self.grow_scratches(1);
        attend_packed_core(
            q,
            k,
            vt,
            nq,
            nk,
            d,
            self.cfg.causal,
            None,
            self.cfg.block_q,
            self.cfg.two_level_p,
            None,
            &mut self.scratches[0],
        )
    }

    /// Single-token decode over the paged FP4 KV cache, all heads of one
    /// layer at once: `q` and `out` are `(heads × head_dim)` — exactly one
    /// model row of a batched decode step.
    ///
    /// Quantized configs stream sealed pages in the packed domain
    /// (`PagedKvCache::attend_decode`); an [`AttnConfig::f32`] session is
    /// the materialising gather + f32 baseline — the A/B switch the decode
    /// server used to carry as a bool is now just a config.
    ///
    /// The paged path has no smoothing / two-level-P / dequant-backend
    /// variants; a quantized config carrying those knobs is rejected
    /// rather than silently computed with a different kernel.
    pub fn decode(
        &mut self,
        cache: &PagedKvCache,
        seq: u64,
        layer: usize,
        q: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        self.decode_slot(cache, cache.slot(seq)?, layer, q, out)
    }

    /// [`AttnEngine::decode`] by [`SeqSlot`] handle — the serving hot
    /// path. The handle indexes the cache's slot table directly, so a
    /// shard worker that resolves it once at admission does **zero** map
    /// lookups per decoded token (the u64-keyed `decode` resolves on every
    /// call).
    pub fn decode_slot(
        &mut self,
        cache: &PagedKvCache,
        slot: SeqSlot,
        layer: usize,
        q: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        self.ensure_paged_config("decode")?;
        let d = cache.head_dim();
        ensure!(
            q.len() == out.len() && !q.is_empty() && q.len() % d == 0,
            "q/out must be heads x head_dim={d}"
        );
        let heads = q.len() / d;
        for head in 0..heads {
            let (qh, oh) = (&q[head * d..(head + 1) * d], &mut out[head * d..(head + 1) * d]);
            if self.cfg.quantized() {
                cache.attend_decode_at(slot, layer, head, qh, oh, &mut self.decode_scratch)?;
            } else {
                let (kc, vc) = cache.gather_at(slot, layer, head)?;
                let nk = kc.len() / d;
                ensure!(nk > 0, "slot {} has no cached tokens", slot.index());
                let o = attend_f32_core(qh, &kc, &vc, 1, nk, d, false);
                oh.copy_from_slice(&o.o);
            }
        }
        Ok(())
    }

    /// Batched multi-query prefill over the paged FP4 KV cache: attend the
    /// **last `nq` cached tokens'** queries in one pass, with aligned-ends
    /// causality (query i sees keys `0 ..= len − nq + i`). `q` and `out`
    /// are `(heads × nq × head_dim)` row-major; returns the `(heads × nq)`
    /// logsumexps.
    ///
    /// Prefill is causal **by construction** — the queries are the cache's
    /// own newest tokens, each allowed to see its own prefix; the config's
    /// `causal` flag (which governs [`AttnEngine::forward`]) is not
    /// consulted here, exactly as `decode`'s single trailing query always
    /// sees the whole cache.
    ///
    /// This is the ROADMAP "batched multi-query decode" lever: one page
    /// walk per query instead of one full `decode` call per token — the
    /// per-call sequence lookup, query-cache probe, and accumulator setup
    /// amortise across the prompt (see the `kvcache_serve` bench's
    /// `prefill` scenario for the recorded comparison).
    pub fn prefill(
        &mut self,
        cache: &PagedKvCache,
        seq: u64,
        layer: usize,
        q: &[f32],
        nq: usize,
        out: &mut [f32],
    ) -> Result<Vec<f32>> {
        self.prefill_slot(cache, cache.slot(seq)?, layer, q, nq, out)
    }

    /// [`AttnEngine::prefill`] by [`SeqSlot`] handle — batched prompt
    /// admission without the per-call id resolution (see
    /// [`AttnEngine::decode_slot`]).
    pub fn prefill_slot(
        &mut self,
        cache: &PagedKvCache,
        slot: SeqSlot,
        layer: usize,
        q: &[f32],
        nq: usize,
        out: &mut [f32],
    ) -> Result<Vec<f32>> {
        self.ensure_paged_config("prefill")?;
        let d = cache.head_dim();
        ensure!(nq > 0, "prefill needs at least one query");
        ensure!(
            q.len() == out.len() && q.len() % (nq * d) == 0 && !q.is_empty(),
            "q/out must be heads x nq={nq} x head_dim={d}"
        );
        let heads = q.len() / (nq * d);
        let mut lse = vec![0.0f32; heads * nq];
        for head in 0..heads {
            let qh = &q[head * nq * d..(head + 1) * nq * d];
            let oh = &mut out[head * nq * d..(head + 1) * nq * d];
            let lh = &mut lse[head * nq..(head + 1) * nq];
            if self.cfg.quantized() {
                let scratch = &mut self.decode_scratch;
                cache.attend_prefill_at(slot, layer, head, qh, nq, oh, lh, scratch)?;
            } else {
                let (kc, vc) = cache.gather_at(slot, layer, head)?;
                let nk = kc.len() / d;
                ensure!(nq <= nk, "prefill of {nq} queries over {nk} cached tokens");
                let o = attend_f32_core(qh, &kc, &vc, nq, nk, d, true);
                oh.copy_from_slice(&o.o);
                lh.copy_from_slice(&o.lse);
            }
        }
        Ok(lse)
    }
}

/// One head's forward under `cfg` — the single dispatch point every
/// engine path funnels through.
#[allow(clippy::too_many_arguments)]
fn run_head(
    cfg: &AttnConfig,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scratch: &mut AttnScratch,
) -> AttnOutput {
    match (cfg.precision, cfg.backend) {
        (Precision::F32, _) => attend_f32_core(q, k, v, nq, nk, d, cfg.causal),
        (_, Backend::Dequant) => attend_quantized_dequant(
            q,
            k,
            v,
            nq,
            nk,
            d,
            cfg.causal,
            cfg.smooth,
            cfg.two_level_p,
            cfg.block_q,
        ),
        (_, Backend::Packed) => attend_quantized(
            q,
            k,
            v,
            nq,
            nk,
            d,
            cfg.causal,
            cfg.smooth,
            cfg.two_level_p,
            cfg.block_q,
            scratch,
        ),
    }
}

/// One head's training forward: `(O + lse, O′)`.
#[allow(clippy::too_many_arguments)]
fn run_head_train(
    cfg: &AttnConfig,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scratch: &mut AttnScratch,
) -> (AttnOutput, Vec<f32>) {
    if cfg.precision == Precision::F32 {
        let out = attend_f32_core(q, k, v, nq, nk, d, cfg.causal);
        let o_prime = out.o.clone();
        (out, o_prime)
    } else {
        attend_quantized_train(
            q,
            k,
            v,
            nq,
            nk,
            d,
            cfg.causal,
            cfg.smooth,
            cfg.two_level_p,
            cfg.block_q,
            scratch,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn parse_covers_both_old_vocabularies() {
        // Forward semantics of the old Variant::parse...
        assert_eq!(AttnConfig::parse("f32").unwrap().precision, Precision::F32);
        assert_eq!(AttnConfig::parse("bf16").unwrap().precision, Precision::F32);
        assert_eq!(AttnConfig::parse("fp4").unwrap().precision, Precision::Fp4);
        assert_eq!(AttnConfig::parse("qat").unwrap().precision, Precision::Fp4);
        let sage = AttnConfig::parse("sage3").unwrap();
        assert_eq!(sage.precision, Precision::Sage3);
        assert!(sage.smooth && sage.two_level_p);
        // ...and the backward switches of the old QatVariant::parse.
        assert_eq!(AttnConfig::parse("attn_qat").unwrap().bwd, BwdSwitches::MATCHED);
        assert_eq!(AttnConfig::parse("dropin").unwrap().bwd, BwdSwitches::STOCK);
        assert!(!AttnConfig::parse("qat_no_o_prime").unwrap().bwd.high_prec_o);
        assert!(!AttnConfig::parse("qat_no_fq_p").unwrap().bwd.fq_p);
    }

    #[test]
    fn variant_name_round_trips_and_reflects() {
        // Every parseable name resolves back to a name that re-parses to
        // the same config (aliases collapse to their canonical spelling).
        for name in AttnConfig::VARIANT_NAMES {
            let cfg = AttnConfig::parse(name).unwrap();
            let back = cfg.variant_name();
            assert_eq!(AttnConfig::parse(back).unwrap(), cfg, "{name} -> {back}");
        }
        // Knobs no preset pins don't break resolution...
        assert_eq!(AttnConfig::fp4().with_causal(true).with_block_q(64).variant_name(), "fp4");
        // ...while genuinely off-preset configs report custom.
        assert_eq!(AttnConfig::fp4().with_smooth(true).variant_name(), "custom");
        let doc = AttnConfig::attn_qat().with_causal(true).to_json();
        assert_eq!(doc.get("variant").as_str(), Some("attn_qat"));
        assert_eq!(doc.get("precision").as_str(), Some("fp4"));
        assert_eq!(doc.get("causal"), &Json::Bool(true));
        assert_eq!(doc.get("bwd").get("high_prec_o"), &Json::Bool(true));
    }

    #[test]
    fn parse_error_lists_valid_names() {
        let err = AttnConfig::parse("nope").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("'nope'"), "{msg}");
        for name in AttnConfig::VARIANT_NAMES {
            assert!(msg.contains(name), "error must list '{name}': {msg}");
        }
    }

    #[test]
    fn head_accessors_slice_the_batch() {
        let (h, n, d) = (3usize, 8usize, 16usize);
        let mut rng = Rng::new(71);
        let q = rng.normal_vec(h * n * d, 0.0, 1.0);
        let k = rng.normal_vec(h * n * d, 0.0, 1.0);
        let v = rng.normal_vec(h * n * d, 0.0, 1.0);
        let mut engine = AttnEngine::new(AttnConfig::fp4());
        let out = engine.forward(&q, &k, &v, h, n, n, d);
        assert_eq!(out.o.len(), h * n * d);
        assert_eq!(out.lse.len(), h * n);
        for head in 0..h {
            assert_eq!(out.head_o(head), &out.o[head * n * d..(head + 1) * n * d]);
            assert_eq!(out.head_lse(head), &out.lse[head * n..(head + 1) * n]);
        }
    }

    #[test]
    fn forward_train_o_matches_forward_bitwise() {
        let (h, n, d) = (2usize, 8usize, 32usize);
        let mut rng = Rng::new(72);
        let q = rng.normal_vec(h * n * d, 0.0, 1.0);
        let k = rng.normal_vec(h * n * d, 0.0, 1.0);
        let v = rng.normal_vec(h * n * d, 0.0, 1.0);
        for cfg in [
            AttnConfig::fp4().with_causal(true),
            AttnConfig::f32(),
            AttnConfig::sage3(),
            AttnConfig::attn_qat().with_smooth(true),
        ] {
            let mut engine = AttnEngine::new(cfg);
            let fwd = engine.forward(&q, &k, &v, h, n, n, d);
            let train = engine.forward_train(&q, &k, &v, h, n, n, d);
            assert_eq!(train.o, fwd.o);
            assert_eq!(train.lse, fwd.lse);
            if cfg.quantized() {
                assert_ne!(train.o_prime, train.o, "O' uses unquantized P");
            } else {
                assert_eq!(train.o_prime, train.o, "f32 session: O' == O");
            }
        }
    }
}
