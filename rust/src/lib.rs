//! # attn-qat — Attn-QAT reproduction (L3 runtime)
//!
//! Rust coordinator for the three-layer Attn-QAT stack (see DESIGN.md):
//! JAX/Pallas author the models and kernels at build time; this crate owns
//! everything that runs — the PJRT runtime, training orchestration, the
//! synthetic-data pipeline, evaluation, the NVFP4 format library, the
//! real-quant attention engines, the FP4 KV cache + decode server, and the
//! experiment drivers that regenerate every table and figure of the paper.
//!
//! Module map:
//! * substrates: [`json`], [`rng`], [`tensor`], [`bench`], [`config`]
//! * numeric formats: [`formats`] (E2M1 / E4M3 / E8M0 / NVFP4 / MXFP4)
//! * runtime: [`runtime`] (PJRT + artifact registry)
//! * engines: [`attention`] (f32 / real-quant FP4 / Sage3)
//! * training: [`qat`] (native FP4-recomputed backward + STE),
//!   [`model`] (QatModel / TrainSession — the native train→serve stack)
//! * pipeline: [`data`], [`coordinator`], [`eval`]
//! * serving: [`kvcache`], [`serve`]
//! * observability: [`telemetry`] (metrics registry, JSON reflection, spans)
//! * analysis: [`perfmodel`], [`experiments`]

pub mod bench;
pub mod config;
pub mod json;
pub mod rng;
pub mod tensor;

pub mod formats;

pub mod attention;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod kvcache;
pub mod model;
pub mod perfmodel;
pub mod qat;
pub mod runtime;
pub mod serve;
pub mod telemetry;
