//! Deterministic splittable RNG (substrate).
//!
//! All data generation (corpus, tasks, latents, noise, eval sampling) flows
//! through this SplitMix64-seeded xoshiro256** generator so every experiment
//! is exactly reproducible from the seeds in its config. `split()` derives
//! statistically independent child streams from a label — the counter-based
//! analogue of `jax.random.split`.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// One SplitMix64 step: advances `state` and returns the mixed output.
/// Also serves as the crate's stateless integer mixer (e.g. the serve
/// cluster's request-id router hashes with one step from `id`).
pub(crate) fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream for `label` without perturbing
    /// `self` (hash-combine of the current state and the label).
    pub fn split(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in label.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        for s in self.s {
            h = (h ^ s).wrapping_mul(0x100000001b3);
        }
        Rng::new(h)
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (uses two uniforms per pair).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_vec(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| mean + std * self.normal()).collect()
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_independent_and_stable() {
        let root = Rng::new(7);
        let mut c1 = root.split("data");
        let mut c2 = root.split("data");
        let mut c3 = root.split("init");
        assert_eq!(c1.next_u64(), c2.next_u64());
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(3);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
