//! Minimal JSON parser/serializer (substrate).
//!
//! The offline build has no `serde`/`serde_json`; this module implements the
//! subset of JSON the repo needs: artifact metadata, golden vectors, and
//! experiment result files. Full RFC 8259 value model, recursive-descent
//! parser, `f64` numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Array of numbers -> Vec<f32> (common case for golden vectors).
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.s.get(self.i..self.i + 4).ok_or_else(|| self.err("bad \\u"))?,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.s.len() && self.s[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.s[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.s[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for src in ["null", "true", "false", "3.5", "-7", "\"hi\\n\""] {
            let v = Json::parse(src).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""éx""#).unwrap();
        assert_eq!(v.as_str(), Some("éx"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo → world\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → world"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn f32_vec() {
        let v = Json::parse("[1.5, 2, -3]").unwrap();
        assert_eq!(v.to_f32_vec().unwrap(), vec![1.5, 2.0, -3.0]);
    }

    #[test]
    fn serialize_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
