//! API-shaped stub of the `xla` crate (xla-rs).
//!
//! The real bindings link `xla_extension` (the XLA C++ runtime), which the
//! offline build environment does not ship. This stub mirrors exactly the
//! surface `attn_qat::runtime` uses so the crate builds and tests run
//! everywhere; [`PjRtClient::cpu`] returns an error, which makes
//! `Runtime::new` fail cleanly and lets every artifact-backed code path
//! (integration tests, artifact benches, the serve demo) gate itself off.
//!
//! To run compiled HLO artifacts for real, replace the `xla` path
//! dependency in `rust/Cargo.toml` with an xla-rs checkout — no source
//! change is needed on the `attn_qat` side.

use std::fmt;

/// Stub error: carries the reason the backend is unavailable.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla backend unavailable (stub build without the XLA C++ runtime)".to_string(),
    ))
}

/// PJRT client handle (never constructible in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real crate builds a CPU PJRT client; the stub always errors.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Loaded executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer (never constructible in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal (never constructible in the stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
