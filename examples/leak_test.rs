//! §Perf regression probe: RSS growth per train step must be ~0.
//! (Guards the `execute_b` fix for the xla crate's literal-execute leak —
//! see EXPERIMENTS.md §Perf.)
use attn_qat::coordinator::{LrSchedule, Trainer};
use attn_qat::data::corpus::Corpus;
use attn_qat::runtime::Runtime;
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    for l in s.lines() { if l.starts_with("VmRSS") {
        return l.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap()/1024.0; } }
    0.0
}
fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(std::path::Path::new("artifacts"))?;
    let mut t = Trainer::new(&rt, "lm_init_tiny", "lm_train_f32_tiny", 1, LrSchedule::Constant(1e-3))?;
    let mut c = Corpus::new(1);
    let b = c.next_batch(2, 64);
    let vals = vec![b.token_value(), b.mask_value()];
    t.step(&vals)?;
    let r0 = rss_mb();
    for i in 0..200 { t.step(&vals)?; if i % 50 == 0 { println!("step {i}: rss {:.1} MB (+{:.2}/step)", rss_mb(), (rss_mb()-r0)/(i+1) as f64); } }
    println!("final: +{:.3} MB/step", (rss_mb()-r0)/200.0);
    Ok(())
}
