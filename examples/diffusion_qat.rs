//! Diffusion-proxy QAT demo: briefly train the rectified-flow model with
//! Attn-QAT, sample "video" latents with the Euler ODE sampler, and score
//! them with the VBench-proxy metrics — the Table-1/2 pipeline in miniature.
//!
//! ```bash
//! make artifacts && cargo run --release --example diffusion_qat
//! ```

use attn_qat::coordinator::{LrSchedule, Trainer};
use attn_qat::data::latents::LatentGen;
use attn_qat::eval::video::{reference_stats, video_metrics};
use attn_qat::runtime::{Runtime, Value};
use attn_qat::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::var("STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(120);
    let size = "tiny";
    let rt = Runtime::new(&Runtime::default_dir())?;
    let train_art = format!("diff_train_qat_{size}");
    let meta = rt.meta(&train_art)?;
    let batch = meta.usize_field("batch").unwrap();
    let model = meta.raw.get("model").clone();
    let frames = model.get("frames").as_usize().unwrap();
    let dl = model.get("latent_dim").as_usize().unwrap();
    println!("diffusion-proxy Attn-QAT: {frames} frames x {dl} dims, {steps} steps\n");

    let mut trainer = Trainer::new(
        &rt,
        &format!("diff_init_{size}"),
        &train_art,
        7,
        LrSchedule::Cosine { warmup: 10, peak: 2e-3, total: steps, floor_frac: 0.1 },
    )?;
    let mut gen = LatentGen::new(7, frames, dl);
    trainer.run(
        steps,
        (steps / 10).max(1),
        |_| gen.next_batch(batch).values().to_vec(),
        |m| println!("step {:>4} flow-matching loss {:.4} gnorm {:.3}", m.step, m.loss, m.grad_norm),
    )?;

    // Sample clips: integrate the probability-flow ODE t: 1 -> 0.
    let sample_steps = 16;
    let n_clips = 16;
    let mut clips = Vec::new();
    let mut produced = 0;
    while produced < n_clips {
        let mut x = Tensor::new(vec![batch, frames, dl], gen.noise_batch(batch))?;
        let dt = 1.0 / sample_steps as f32;
        for s in 0..sample_steps {
            let t = 1.0 - s as f32 * dt;
            let mut inputs: Vec<Value> =
                trainer.state.params.iter().cloned().map(Value::F32).collect();
            inputs.push(Value::F32(x));
            inputs.push(Value::F32(Tensor::new(vec![batch], vec![t; batch])?));
            inputs.push(Value::F32(Tensor::new(vec![batch], vec![dt; batch])?));
            x = rt.run(&format!("diff_sample_fp4_{size}"), &inputs)?.remove(0);
        }
        let take = (n_clips - produced).min(batch);
        clips.extend_from_slice(&x.data[..take * frames * dl]);
        produced += take;
    }

    // VBench-proxy metrics against the known generator.
    let mut ref_gen = LatentGen::new(99, frames, dl);
    let mut ref_data = Vec::new();
    for _ in 0..64 {
        ref_data.extend(ref_gen.sample());
    }
    let stats = reference_stats(&ref_data, 64, frames, dl);
    let m = video_metrics(&clips, n_clips, frames, dl, &stats);
    println!("\nVBench-proxy metrics for {n_clips} sampled clips (FP4 inference):");
    println!("  imaging quality        {:.4}", m.imaging_quality);
    println!("  aesthetic quality      {:.4}", m.aesthetic_quality);
    println!("  subject consistency    {:.4}", m.subject_consistency);
    println!("  background consistency {:.4}", m.background_consistency);
    println!("  temporal flickering    {:.4}", m.temporal_flickering);
    println!("  motion smoothness      {:.4}", m.motion_smoothness);
    println!("  dynamic degree         {:.4}", m.dynamic_degree);
    println!("  overall                {:.4}", m.overall);
    Ok(())
}
