//! Figure-4 demo: fake-quant training forward (compiled HLO, both jnp and
//! Pallas paths) vs the real-quant packed-4-bit Rust engine on identical
//! inputs — the train/inference consistency check.
//!
//! ```bash
//! make artifacts && cargo run --release --example kernel_consistency
//! ```

use attn_qat::attention::{AttnConfig, AttnEngine};
use attn_qat::rng::Rng;
use attn_qat::runtime::{Runtime, Value};
use attn_qat::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    let (b, h, n, d) = (1usize, 4usize, 256usize, 64usize);
    let mut rng = Rng::new(0xf14);
    let numel = b * h * n * d;
    let q = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;
    let k = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;
    let v = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;

    println!("attention {b}x{h}x{n}x{d}; comparing per variant:\n");
    println!(
        "{:<8} {:<46} {:>12} {:>12} {:>10}",
        "variant", "pair", "max abs", "mean abs", "cosine"
    );
    for variant in ["f32", "fp4", "sage3"] {
        let fast = rt.run(
            &format!("attn_{variant}_s{n}_d{d}"),
            &[Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())],
        )?;
        let pallas = rt.run(
            &format!("attn_{variant}_pallas_s{n}_d{d}"),
            &[Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())],
        )?;
        // One multi-head engine session per variant; block_q = 64 matches
        // the artifact's Q tile for sage3 bit parity.
        let mut engine = AttnEngine::new(AttnConfig::parse(variant)?.with_block_q(64));
        let out = engine.forward(&q.data, &k.data, &v.data, h, n, n, d);
        let native = Tensor::new(vec![b, h, n, d], out.o)?;
        for (pair, a, bb) in [
            ("fake-quant HLO (jnp) vs real-quant rust", &fast[0], &native),
            ("fake-quant HLO (pallas) vs real-quant rust", &pallas[0], &native),
            ("jnp vs pallas fake-quant", &fast[0], &pallas[0]),
        ] {
            println!(
                "{:<8} {:<46} {:>12.3e} {:>12.3e} {:>10.6}",
                variant,
                pair,
                a.max_abs_diff(bb),
                a.mean_abs_diff(bb),
                a.cosine_sim(bb)
            );
        }
        println!();
    }
    println!("(paper's Fig. 4 claim: the two implementations are visually indistinguishable;\n here: cosine ~ 1 and max error at the quantization-noise scale for jnp-vs-real,\n tile-order effects only for pallas-vs-jnp)");
    Ok(())
}
