//! Batched decode over the NVFP4 paged KV cache (§5 future work, built).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_fp4_kv
//! ```
//!
//! Non-attention compute runs as compiled per-layer HLO; attention runs
//! natively over 4-bit KV pages. Reports tokens/s, per-request latency and
//! the KV-memory saving vs an f32 cache.

use attn_qat::runtime::{Runtime, Value};
use attn_qat::serve::{DecodeServer, Request};
use attn_qat::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let size = std::env::var("SIZE").unwrap_or_else(|_| "tiny".to_string());
    let n_req: usize = std::env::var("REQUESTS").ok().and_then(|s| s.parse().ok()).unwrap_or(12);
    let max_new: usize = std::env::var("MAX_NEW").ok().and_then(|s| s.parse().ok()).unwrap_or(32);

    let rt = Runtime::new(&Runtime::default_dir())?;
    let meta = rt.meta(&format!("lm_init_{size}"))?;
    let names = meta.param_names();
    // Prefer a trained checkpoint (run `repro exp table4` or train_llm
    // first); otherwise a fresh init still demonstrates the machinery.
    let params = attn_qat::experiments::common::load_cached(&format!("lm_base_{size}"), &names)
        .unwrap_or(rt.run(&format!("lm_init_{size}"), &[Value::scalar_i32(42)])?);
    let weights: Vec<(String, Tensor)> = names.into_iter().zip(params).collect();

    let mut server = DecodeServer::new(&rt, &size, weights)?;
    let prompts = ["C:abcde#", "R:hello#", "U:world#", "S:dcba#", "Q:a=x,b=y,c=z,?b#"];
    for i in 0..n_req {
        server.submit(Request {
            id: i as u64 + 1,
            prompt: prompts[i % prompts.len()].as_bytes().to_vec(),
            max_new_tokens: max_new,
            temperature: 0.0,
        });
    }
    println!("serving {n_req} requests (continuous batching, FP4 paged KV)...\n");
    let t0 = std::time::Instant::now();
    let done = server.run()?;
    let wall = t0.elapsed().as_secs_f64();

    let mut lat: Vec<f64> = done.iter().map(|c| c.wall_ms).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for c in done.iter().take(5) {
        println!(
            "req {:>3}: +{:>3} tokens, {:>8.1} ms   {:?}",
            c.id,
            c.new_tokens,
            c.wall_ms,
            String::from_utf8_lossy(&c.text)
        );
    }
    let stats = server.stats;
    println!("\n--- serving summary ---");
    println!("requests      : {}", done.len());
    println!("tokens decoded: {}", stats.tokens_decoded);
    println!("throughput    : {:.1} tok/s", stats.tokens_decoded as f64 / wall);
    println!(
        "latency p50/p95: {:.0} / {:.0} ms",
        lat[lat.len() / 2],
        lat[(lat.len() as f64 * 0.95) as usize % lat.len()]
    );
    println!(
        "KV cache      : {} B packed vs {} B f32-equiv = {:.1}x reduction",
        stats.kv_bytes,
        stats.kv_bytes_f32_equiv,
        stats.kv_bytes_f32_equiv as f64 / stats.kv_bytes.max(1) as f64
    );
    Ok(())
}
