//! Quickstart: load compiled artifacts, run them, and cross-check the
//! NVFP4 numeric formats between all three layers.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the public API surface in ~5 minutes of reading:
//! `Runtime` (PJRT + registry), `formats` (software NVFP4), and the native
//! attention engines — and proves the JAX-lowered HLO and the Rust format
//! library agree **bit-exactly**.

use attn_qat::attention::{AttnConfig, AttnEngine};
use attn_qat::formats::analysis::error_stats;
use attn_qat::formats::block::nvfp4_fake_quant_row;
use attn_qat::formats::PackedNvfp4;
use attn_qat::rng::Rng;
use attn_qat::runtime::{Runtime, Value};
use attn_qat::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    println!("registry: {} artifacts\n", rt.registry().len());

    // --- 1. NVFP4 quantization: HLO (fake quant) vs formats lib ---------
    let mut rng = Rng::new(1);
    let x: Vec<f32> = rng.normal_vec(1024 * 64, 0.0, 1.5);
    let t = Tensor::new(vec![1024, 64], x.clone())?;
    let hlo_out = rt.run("quant_fake_1024x64", &[Value::F32(t.clone())])?;
    let pallas_out = rt.run("quant_fake_pallas_1024x64", &[Value::F32(t)])?;

    let mut rust_out = x.clone();
    for row in rust_out.chunks_mut(64) {
        nvfp4_fake_quant_row(row);
    }
    let diff_jnp = max_diff(&hlo_out[0].data, &rust_out);
    let diff_pal = max_diff(&pallas_out[0].data, &rust_out);
    println!("fake-quant agreement (65536 elements):");
    println!("  jnp HLO    vs rust formats: max diff {diff_jnp:e}");
    println!("  pallas HLO vs rust formats: max diff {diff_pal:e}");
    assert_eq!(diff_jnp, 0.0);
    assert_eq!(diff_pal, 0.0);

    // --- 2. What FP4 costs: quantization error + storage ----------------
    let stats = error_stats(&x, &rust_out, 1e-3);
    let packed = PackedNvfp4::quantize(&x, 1024, 64)?;
    println!("\nNVFP4 on N(0, 1.5) data:");
    println!(
        "  snr {:.1} dB | max abs err {:.3} | mse {:.2e}",
        stats.snr_db, stats.max_abs, stats.mse
    );
    println!(
        "  packed storage: {} bytes = {:.1} bits/elem ({:.1}x smaller than f32)",
        packed.memory_bytes(),
        packed.memory_bytes() as f32 * 8.0 / (1024.0 * 64.0),
        packed.compression_vs_f32()
    );

    // --- 3. Attention: f32 vs real-quant FP4 vs Sage3 engines -----------
    let (n, d) = (128usize, 64usize);
    let q = rng.normal_vec(n * d, 0.0, 1.0);
    let k = rng.normal_vec(n * d, 0.0, 1.0);
    let v = rng.normal_vec(n * d, 0.0, 1.0);
    let exact = AttnEngine::new(AttnConfig::f32()).forward(&q, &k, &v, 1, n, n, d);
    println!("\nattention output error vs f32 ({n}x{d}, native engines):");
    for variant in ["fp4", "sage3"] {
        let mut engine = AttnEngine::new(AttnConfig::parse(variant)?);
        let out = engine.forward(&q, &k, &v, 1, n, n, d);
        let s = error_stats(&exact.o, &out.o, 1e-3);
        println!("  {variant}: snr {:.1} dB, max abs err {:.4}", s.snr_db, s.max_abs);
    }

    // --- 4. Run the compiled attention artifact -------------------------
    let shape = vec![1usize, 4, 256, 64];
    let numel: usize = shape.iter().product();
    let mk = |r: &mut Rng| Tensor::new(shape.clone(), r.normal_vec(numel, 0.0, 1.0)).unwrap();
    let (tq, tk, tv) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let o = rt.run(
        "attn_fp4_s256_d64",
        &[Value::F32(tq), Value::F32(tk), Value::F32(tv)],
    )?;
    println!(
        "\ncompiled FP4 attention artifact: output shape {:?}, first vals {:?}",
        o[0].shape,
        &o[0].data[..4]
    );
    println!("\nquickstart OK");
    Ok(())
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}
