//! End-to-end driver (the EXPERIMENTS.md §E2E run): continued-train the
//! small byte-level LM with **Attn-QAT** on the synthetic corpus for a few
//! hundred steps, logging the loss curve, then evaluate held-out
//! perplexity and the benchmark suites in FP4.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_llm            # ~300 steps
//! STEPS=50 cargo run --release --example train_llm   # quicker
//! ```
//!
//! Everything on the request path is Rust: data generation, batching, the
//! train-step executions, metric logging, checkpointing, eval.

use std::path::Path;

use attn_qat::coordinator::{checkpoint, LrSchedule, Trainer};
use attn_qat::data::corpus::Corpus;
use attn_qat::data::tasks::MC_SUITES;
use attn_qat::eval::{mc_accuracy, perplexity};
use attn_qat::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::var("STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let size = std::env::var("SIZE").unwrap_or_else(|_| "small".to_string());
    let seed = 42u64;

    let rt = Runtime::new(&Runtime::default_dir())?;
    let train_art = format!("lm_train_qat_{size}");
    let meta = rt.meta(&train_art)?;
    let batch = meta.usize_field("batch").unwrap();
    let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
    let n_params: usize = meta.param_names().len();
    println!(
        "Attn-QAT continued training: model '{size}' ({} param tensors), {steps} steps, batch {batch} x seq {seq}\n",
        n_params
    );

    let mut trainer = Trainer::new(
        &rt,
        &format!("lm_init_{size}"),
        &train_art,
        seed as i32,
        LrSchedule::Cosine { warmup: steps / 20 + 1, peak: 1e-3, total: steps, floor_frac: 0.1 },
    )?;

    let mut corpus = Corpus::new(seed);
    let t0 = std::time::Instant::now();
    trainer.run(
        steps,
        (steps / 25).max(1),
        |_| {
            let b = corpus.next_batch(batch, seq);
            vec![b.token_value(), b.mask_value()]
        },
        |m| {
            println!(
                "step {:>5}  loss {:.4}  grad_norm {:>8.3}  lr {:.2e}  {:>6.0} ms/step",
                m.step, m.loss, m.grad_norm, m.lr, m.wall_ms
            );
        },
    )?;
    let wall = t0.elapsed().as_secs_f64();
    let toks = (steps * batch * seq) as f64;
    println!(
        "\ntrained {steps} steps ({:.0} tokens) in {:.1}s = {:.0} tok/s; diverged={}",
        toks,
        wall,
        toks / wall,
        trainer.diverged()
    );

    // Loss curve summary (the E2E evidence for EXPERIMENTS.md).
    let h = &trainer.history;
    println!("\nloss curve (every ~{} steps):", (steps / 12).max(1));
    for m in h.iter().step_by((steps / 12).max(1)) {
        let bar_len = ((m.loss.min(6.0) / 6.0) * 50.0) as usize;
        println!("  {:>5} {:>8.4} {}", m.step, m.loss, "#".repeat(bar_len));
    }

    // Checkpoint.
    let names = meta.param_names();
    let named: Vec<(String, &attn_qat::tensor::Tensor)> = names
        .iter()
        .cloned()
        .zip(trainer.state.params.iter())
        .collect();
    let ckpt = Path::new("results/ckpt/train_llm_example.ckpt");
    checkpoint::save(ckpt, &named)?;
    println!("\ncheckpoint -> {}", ckpt.display());

    // FP4 evaluation (the trained model *serves* in FP4 attention).
    let eval_art = format!("lm_eval_fp4_{size}");
    let mut held_out = Corpus::new(seed ^ 0xeeee);
    let ppl = perplexity(&rt, &eval_art, &trainer.state.params, &mut held_out, 3)?;
    println!("\nheld-out perplexity (FP4 attention): {ppl:.4}");
    for suite in MC_SUITES {
        let acc = mc_accuracy(&rt, &eval_art, &trainer.state.params, suite, 30, seed + 9)?;
        println!("  suite {suite:<8} accuracy {acc:.3}");
    }
    Ok(())
}
