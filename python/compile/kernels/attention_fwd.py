"""Pallas forward kernels for Attn-QAT (Algorithms 1 & 2).

TPU-adapted layout (DESIGN.md §3): the paper's CUDA/Triton threadblock over
(batch·head, q-tile) becomes the Pallas **grid** ``(BH, Tq)``; the Q tile is
staged into VMEM by its BlockSpec while K/V tiles stream through an inner
``fori_loop`` (``pl.ds`` dynamic slices) — the HBM↔VMEM schedule the paper
expresses with shared-memory staging. Both matmuls per (i, j) tile pair hit
the MXU; the extra high-precision accumulator ``O'`` of Alg. 2 line 13 is a
second ``(Bq, d)`` f32 VMEM accumulator and costs no extra HBM traffic.

``interpret=True`` is mandatory here: the CPU PJRT client cannot execute
Mosaic custom-calls, and these kernels are lowered into the exported HLO
artifacts that the Rust runtime loads.

All kernels take pre-quantized inputs ``Q^F/K^F/V^F`` (Alg. 2 line 2 happens
in its own fake-quant kernel below, mirroring the paper's separation of
input quantization from the fused loop); the probability fake-quant happens
**inside** the loop, as in Alg. 1 line 12 / Alg. 2 line 10.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import nvfp4
from .ref import NEG_INF, QatConfig, quantize_p

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


# --------------------------------------------------------------------------
# Fake-quantization kernel (Alg. 2 line 2)
# --------------------------------------------------------------------------


def _fake_quant_kernel(x_ref, o_ref, *, axis: int, block: int):
    x = x_ref[...]
    o_ref[...] = nvfp4.fake_quant(x, axis=axis, block=block)


def fake_quant_pallas(x: jnp.ndarray, axis: int = -1, block: int = nvfp4.NVFP4_BLOCK):
    """NVFP4 fake quantization φ⁻¹(φ(X)) as a Pallas kernel.

    Grid over the leading axis; each step fake-quantizes one row-slab in
    VMEM. ``axis`` is the micro-scaling block axis (must not be axis 0).
    """
    if x.ndim < 2:
        raise ValueError("fake_quant_pallas expects >= 2-D input")
    axis = axis % x.ndim
    if axis == 0:
        raise ValueError("block axis must not be the grid axis")
    slab = (1,) + x.shape[1:]
    return pl.pallas_call(
        functools.partial(_fake_quant_kernel, axis=axis, block=block),
        grid=(x.shape[0],),
        in_specs=[pl.BlockSpec(slab, lambda i: (i,) + (0,) * (x.ndim - 1))],
        out_specs=pl.BlockSpec(slab, lambda i: (i,) + (0,) * (x.ndim - 1)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x)


# --------------------------------------------------------------------------
# Flash forward (Alg. 1 inference / Alg. 2 training)
# --------------------------------------------------------------------------


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref, *rest, cfg: QatConfig, nq: int, nk: int, smooth_q: bool
):
    if smooth_q:
        # SageAttention3: ΔS_ij = q̄_i γ(K_j)ᵀ added back in high precision
        # after the (emulated) FP4 matmul — q̄_i arrives as an extra input.
        dsq_ref, o_ref, op_ref, lse_ref = rest
    else:
        dsq_ref = None
        o_ref, op_ref, lse_ref = rest
    bq, d = q_ref.shape[1], q_ref.shape[2]
    bk = cfg.block_k
    i = pl.program_id(1)
    i0 = i * bq
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qi = q_ref[0, :, :]  # (bq, d) — staged in VMEM by the BlockSpec
    qbar = dsq_ref[0, 0, :] if smooth_q else None

    if cfg.causal:
        # Early exit: key tiles strictly above the diagonal contribute
        # nothing; loop only over the tiles this q-tile can see.
        last_k = i0 + bq - 1 + (nk - nq)
        num_j = jnp.minimum((last_k // bk) + 1, nk // bk)
    else:
        num_j = nk // bk

    def body(j, carry):
        m_i, l_i, acc, acc_hp = carry
        kj = pl.load(k_ref, (0, pl.ds(j * bk, bk), slice(None)))
        vj = pl.load(v_ref, (0, pl.ds(j * bk, bk), slice(None)))
        s = jnp.dot(qi, kj.T)  # MXU pass 1 (Alg.2 l.7)
        if smooth_q:
            s = s + jnp.broadcast_to(jnp.dot(qbar, kj.T), s.shape)
        s = s * scale
        if cfg.causal:
            qpos = i0 + jnp.arange(bq)[:, None] + (nk - nq)
            kpos = j * bk + jnp.arange(bk)[None, :]
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))  # Alg.2 l.8
        alpha = jnp.exp(m_i - m_new)  # Alg.2 l.9
        p = jnp.exp(s - m_new[:, None])
        pf = quantize_p(p, cfg)  # Alg.2 l.10 (fused in VMEM)
        l_i = alpha * l_i + jnp.sum(p, axis=-1)  # Alg.2 l.11
        acc = alpha[:, None] * acc + jnp.dot(pf, vj)  # MXU pass 2 (l.12)
        acc_hp = alpha[:, None] * acc_hp + jnp.dot(p, vj)  # O' accum (l.13)
        return m_new, l_i, acc, acc_hp

    init = (
        jnp.full((bq,), NEG_INF, jnp.float32),
        jnp.zeros((bq,), jnp.float32),
        jnp.zeros((bq, d), jnp.float32),
        jnp.zeros((bq, d), jnp.float32),
    )
    m_i, l_i, acc, acc_hp = jax.lax.fori_loop(0, num_j, body, init)
    inv_l = 1.0 / l_i[:, None]
    o_ref[0, :, :] = acc * inv_l  # Alg.2 l.15
    op_ref[0, :, :] = acc_hp * inv_l
    lse_ref[0, :] = m_i + jnp.log(l_i)


def flash_forward_pallas(qf, kf, vf, cfg: QatConfig, dsq=None):
    """Tiled flash forward over pre-quantized inputs, batched over axis 0.

    Args: ``qf (B, Nq, d)``, ``kf/vf (B, Nk, d)`` — already fake-quantized
    (or raw for the f32 variant); ``dsq (B, Tq, d)`` per-tile q̄ means for
    the smooth-Q fixup (sage3 only). Returns ``(o, o_prime, lse)`` with
    shapes ``(B, Nq, d)``, ``(B, Nq, d)``, ``(B, Nq)``.
    """
    b, nq, d = qf.shape
    nk = kf.shape[1]
    bq, bk = cfg.block_q, cfg.block_k
    if nq % bq or nk % bk:
        raise ValueError(f"seq lens ({nq},{nk}) must divide tiles ({bq},{bk})")
    smooth_q = dsq is not None
    grid = (b, nq // bq)
    kernel = functools.partial(
        _flash_fwd_kernel, cfg=cfg, nq=nq, nk=nk, smooth_q=smooth_q
    )
    in_specs = [
        pl.BlockSpec((1, bq, d), lambda b_, i: (b_, i, 0)),
        pl.BlockSpec((1, nk, d), lambda b_, i: (b_, 0, 0)),
        pl.BlockSpec((1, nk, d), lambda b_, i: (b_, 0, 0)),
    ]
    args = [qf, kf, vf]
    if smooth_q:
        in_specs.append(pl.BlockSpec((1, 1, d), lambda b_, i: (b_, i, 0)))
        args.append(dsq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b_, i: (b_, i, 0)),
            pl.BlockSpec((1, bq, d), lambda b_, i: (b_, i, 0)),
            pl.BlockSpec((1, bq), lambda b_, i: (b_, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, nq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, nq), jnp.float32),
        ],
        interpret=INTERPRET,
    )(*args)


# --------------------------------------------------------------------------
# D = rowsum(dO ⊙ O') preprocess kernel (Alg. 3 line 3)
# --------------------------------------------------------------------------


def _dvec_kernel(do_ref, o_ref, d_ref):
    d_ref[0, :] = jnp.sum(do_ref[0, :, :] * o_ref[0, :, :], axis=-1)


def dvec_pallas(do: jnp.ndarray, o_for_d: jnp.ndarray, block_q: int):
    """The FlashAttention-style backward preprocess: ``D = rowsum(dO ⊙ O*)``.

    ``o_for_d`` is ``O'`` under Fix B (Alg. 3 line 3) or the low-precision
    ``O`` in the Exp. 7 ablation — the caller picks.
    """
    b, nq, _ = do.shape
    return pl.pallas_call(
        _dvec_kernel,
        grid=(b, nq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, do.shape[2]), lambda b_, i: (b_, i, 0)),
            pl.BlockSpec((1, block_q, do.shape[2]), lambda b_, i: (b_, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda b_, i: (b_, i)),
        out_shape=jax.ShapeDtypeStruct((b, nq), jnp.float32),
        interpret=INTERPRET,
    )(do, o_for_d)
