"""Pallas backward kernels for Attn-QAT (Algorithm 3).

Follows the FlashAttention-2 split the paper's Triton kernels use:

* ``dkv`` kernel — grid ``(BH, Tk)``; each step owns one K/V tile, loops
  over the query tiles that can see it, and accumulates ``dK_j``/``dV_j``
  in VMEM (Alg. 3 outer loop).
* ``dq`` kernel  — grid ``(BH, Tq)``; each step owns one Q tile and loops
  over its visible key tiles accumulating ``dQ_i``.

Splitting avoids the cross-tile ``dQ`` accumulation the single-kernel
formulation would need (atomics on GPU, a second pass on TPU) at the cost
of recomputing ``S``/``P`` twice — the same trade FA2 makes.

Ablation switches (which P the ``dV`` matmul sees, which O feeds ``D``,
whether the recomputation uses quantized inputs) are threaded through
``QatConfig`` exactly as in ``ref.flash_backward``; pytest pins the two
implementations together bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .attention_fwd import INTERPRET, dvec_pallas
from .ref import NEG_INF, QatConfig, quantize_p


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref, dk_ref, dv_ref,
    *, cfg: QatConfig, nq: int, nk: int,
):
    bq, bk = cfg.block_q, cfg.block_k
    d = k_ref.shape[2]
    j = pl.program_id(1)
    j0 = j * bk
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    kj = k_ref[0, :, :]
    vj = v_ref[0, :, :]

    if cfg.causal:
        # Query tiles strictly above this key tile's diagonal see nothing.
        first_q = jnp.maximum((j0 - (nk - nq)) // bq, 0)
    else:
        first_q = 0

    def body(i, carry):
        dkj, dvj = carry
        i0 = i * bq
        qi = pl.load(q_ref, (0, pl.ds(i0, bq), slice(None)))
        doi = pl.load(do_ref, (0, pl.ds(i0, bq), slice(None)))
        lse_i = pl.load(lse_ref, (0, pl.ds(i0, bq)))
        d_i = pl.load(dvec_ref, (0, pl.ds(i0, bq)))
        s = jnp.dot(qi, kj.T) * scale  # Alg.3 l.9
        if cfg.causal:
            qpos = i0 + jnp.arange(bq)[:, None] + (nk - nq)
            kpos = j0 + jnp.arange(bk)[None, :]
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse_i[:, None])  # Alg.3 l.10
        pf = quantize_p(p, cfg) if cfg.fq_p_bwd else p  # Alg.3 l.11 (Fix A)
        dvj = dvj + jnp.dot(pf.T, doi)  # Alg.3 l.12
        dp = jnp.dot(doi, vj.T)  # Alg.3 l.13
        ds = p * (dp - d_i[:, None]) * scale  # Alg.3 l.14 (high-precision P)
        dkj = dkj + jnp.dot(ds.T, qi)  # Alg.3 l.16
        return dkj, dvj

    init = (jnp.zeros((bk, d), jnp.float32), jnp.zeros((bk, d), jnp.float32))
    dkj, dvj = jax.lax.fori_loop(first_q, nq // bq, body, init)
    dk_ref[0, :, :] = dkj
    dv_ref[0, :, :] = dvj


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref, dq_ref,
    *, cfg: QatConfig, nq: int, nk: int,
):
    bq, bk = cfg.block_q, cfg.block_k
    d = q_ref.shape[2]
    i = pl.program_id(1)
    i0 = i * bq
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qi = q_ref[0, :, :]
    doi = do_ref[0, :, :]
    lse_i = lse_ref[0, :]
    d_i = dvec_ref[0, :]

    if cfg.causal:
        last_k = i0 + bq - 1 + (nk - nq)
        num_j = jnp.minimum((last_k // bk) + 1, nk // bk)
    else:
        num_j = nk // bk

    def body(j, dqi):
        j0 = j * bk
        kj = pl.load(k_ref, (0, pl.ds(j0, bk), slice(None)))
        vj = pl.load(v_ref, (0, pl.ds(j0, bk), slice(None)))
        s = jnp.dot(qi, kj.T) * scale
        if cfg.causal:
            qpos = i0 + jnp.arange(bq)[:, None] + (nk - nq)
            kpos = j0 + jnp.arange(bk)[None, :]
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse_i[:, None])
        dp = jnp.dot(doi, vj.T)
        ds = p * (dp - d_i[:, None]) * scale
        return dqi + jnp.dot(ds, kj)  # Alg.3 l.15

    dqi = jax.lax.fori_loop(0, num_j, body, jnp.zeros((bq, d), jnp.float32))
    dq_ref[0, :, :] = dqi


def flash_backward_pallas(qb, kb, vb, o, o_prime, lse, do, cfg: QatConfig):
    """Alg. 3 as two Pallas kernels, batched over axis 0.

    ``qb/kb/vb`` are the backward's recomputation inputs — Q^F/K^F/V^F when
    ``cfg.fq_inputs_bwd`` (the caller quantizes), raw otherwise ("drop-in"
    stock-FA backward). Returns ``(dq, dk, dv)`` w.r.t. those inputs; the
    STE (Eq. 7) passes them unchanged to the raw tensors.
    """
    b, nq, d = qb.shape
    nk = kb.shape[1]
    bq, bk = cfg.block_q, cfg.block_k
    if nq % bq or nk % bk:
        raise ValueError(f"seq lens ({nq},{nk}) must divide tiles ({bq},{bk})")

    dvec = dvec_pallas(do, o_prime if cfg.high_prec_o else o, bq)  # Alg.3 l.3

    full_q = pl.BlockSpec((1, nq, d), lambda b_, t: (b_, 0, 0))
    full_k = pl.BlockSpec((1, nk, d), lambda b_, t: (b_, 0, 0))
    full_r = pl.BlockSpec((1, nq), lambda b_, t: (b_, 0))
    tile_q = pl.BlockSpec((1, bq, d), lambda b_, t: (b_, t, 0))
    tile_k = pl.BlockSpec((1, bk, d), lambda b_, t: (b_, t, 0))
    tile_rq = pl.BlockSpec((1, bq), lambda b_, t: (b_, t))

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, cfg=cfg, nq=nq, nk=nk),
        grid=(b, nk // bk),
        in_specs=[full_q, tile_k, tile_k, full_q, full_r, full_r],
        out_specs=[tile_k, tile_k],
        out_shape=[
            jax.ShapeDtypeStruct((b, nk, d), jnp.float32),
            jax.ShapeDtypeStruct((b, nk, d), jnp.float32),
        ],
        interpret=INTERPRET,
    )(qb, kb, vb, do, lse, dvec)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, cfg=cfg, nq=nq, nk=nk),
        grid=(b, nq // bq),
        in_specs=[tile_q, full_k, full_k, tile_q, tile_rq, tile_rq],
        out_specs=tile_q,
        out_shape=jax.ShapeDtypeStruct((b, nq, d), jnp.float32),
        interpret=INTERPRET,
    )(qb, kb, vb, do, lse, dvec)

    return dq, dk, dv
