"""Pure-jnp correctness oracles for the Attn-QAT kernels.

Two levels of reference:

1. ``naive_*`` — the mathematical definition (materialise S and P), used to
   validate the tiled implementations.
2. ``flash_*`` — tile-exact replicas of Algorithms 1–3 written with python
   loops over tiles. The Pallas kernels must match these **bit-for-bit**
   (same op order, same fake-quant placement); pytest enforces it.

All functions operate on unbatched ``(N, d)`` tensors; batching is added by
``vmap`` at the call sites (and by the grid in the Pallas kernels).

Quantization-axis convention (matches FP4MM's micro-scaling layout, which
scales along the **contraction** dimension):
  * ``Q``, ``K`` — blocks along the head dimension ``d`` (contraction of QKᵀ)
  * ``P``       — blocks along the key axis (contraction of P·V)
  * ``V``       — blocks along the token/key axis (contraction of P·V)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import nvfp4

NEG_INF = -1e30  # finite -inf stand-in: keeps exp()/max() NaN-free on tiles


@dataclass(frozen=True)
class QatConfig:
    """Variant switches for the attention forward/backward (paper §2.3, §3.2).

    The named presets used across the repo:

    ===================  ============================================================
    ``f32``              no quantization anywhere (the paper's "BF16" baseline)
    ``fp4``              fake-quant fwd, *stock* FlashAttention bwd ("drop-in", unstable)
    ``qat``              Attn-QAT: fake-quant fwd + matched bwd (Alg. 2 + Alg. 3)
    ``qat_smoothk``      qat + K smoothing (Table 2 Exp. 5)
    ``qat_twolevel``     qat + two-level P quantization (Table 2 Exp. 6)
    ``qat_no_o_prime``   qat w/o the high-precision O' in bwd (Table 2 Exp. 7)
    ``qat_no_fq_p``      qat w/o fake-quant of recomputed P in bwd (Table 2 Exp. 8)
    ``sage3``            inference-only SageAttention3 emulation (K/Q smoothing +
                         two-level P; no bwd)
    ===================  ============================================================
    """

    quantize: bool = True          # fake-quantize Q/K/V/P in the forward
    smooth_k: bool = False         # subtract the global key mean before φ(K)
    smooth_q: bool = False         # per-tile Q smoothing + high-prec ΔS fixup
    two_level_p: bool = False      # SageAttention3 two-level quantization of P
    # Backward switches (the paper's two key fixes):
    fq_p_bwd: bool = True          # Fix A: fake-quant the recomputed P (Alg.3 l.11)
    high_prec_o: bool = True       # Fix B: D = rowsum(dO ⊙ O') (Alg.3 l.3)
    fq_inputs_bwd: bool = True     # bwd uses Q^F/K^F/V^F (False = stock FA bwd)
    causal: bool = False
    block_q: int = 64
    block_k: int = 64


PRESETS = {
    "f32": QatConfig(quantize=False),
    "fp4": QatConfig(fq_p_bwd=False, high_prec_o=False, fq_inputs_bwd=False),
    "qat": QatConfig(),
    "qat_smoothk": QatConfig(smooth_k=True),
    "qat_twolevel": QatConfig(two_level_p=True),
    "qat_no_o_prime": QatConfig(high_prec_o=False),
    "qat_no_fq_p": QatConfig(fq_p_bwd=False),
    "sage3": QatConfig(smooth_k=True, smooth_q=True, two_level_p=True),
}


def preset(name: str, causal: bool = False, block_q: int = 64, block_k: int = 64) -> QatConfig:
    """Look up a preset and apply the run-time shape knobs."""
    import dataclasses

    return dataclasses.replace(
        PRESETS[name], causal=causal, block_q=block_q, block_k=block_k
    )


# --------------------------------------------------------------------------
# Smoothing + fake-quant preprocessing (shared by ref / pallas / custom_vjp)
# --------------------------------------------------------------------------


def preprocess_qkv(q, k, v, cfg: QatConfig):
    """Apply smoothing + fake quantization to Q/K/V per the variant.

    Returns ``(qf, kf, vf, dsq)`` where ``dsq`` is the high-precision
    per-(q-tile) mean vector ``q̄`` needed for the smooth-Q ΔS fixup
    (``None`` unless ``cfg.smooth_q``).

    K smoothing subtracts the global key mean ``k̄`` (Eq. 4). The dropped
    rank-1 term ``Q k̄ᵀ`` is constant per row of S and cancels in softmax,
    so no fixup is needed — this is why the paper ablates Smooth-K only.
    """
    dsq = None
    if cfg.smooth_k:
        k = k - jnp.mean(k, axis=0, keepdims=True)
    if cfg.smooth_q:
        # γ(Q_i) = Q_i - mean(Q_i) per query tile; S gets the high-precision
        # correction ΔS_ij = q̄_i γ(K_j)ᵀ added back after the FP4 matmul.
        nq = q.shape[0]
        bq = cfg.block_q
        means = []
        rows = []
        for i0 in range(0, nq, bq):
            tile = q[i0 : i0 + bq]
            mu = jnp.mean(tile, axis=0, keepdims=True)
            means.append(mu)
            rows.append(tile - mu)
        q = jnp.concatenate(rows, axis=0)
        dsq = jnp.concatenate(means, axis=0)  # (Tq, d)
    if cfg.quantize:
        qf = nvfp4.fake_quant(q, axis=-1)
        kf = nvfp4.fake_quant(k, axis=-1)
        vf = nvfp4.fake_quant(v, axis=0)
    else:
        qf, kf, vf = q, k, v
    return qf, kf, vf, dsq


def quantize_p(p, cfg: QatConfig):
    """Fake-quantize a probability tile along the key axis per the variant."""
    if not cfg.quantize:
        return p
    if cfg.two_level_p:
        return nvfp4.two_level_quant_p(p, axis=-1)
    return nvfp4.fake_quant(p, axis=-1)


def _causal_mask(nq: int, nk: int, i0: int, j0: int, bq: int, bk: int):
    """Mask for block (i0, j0): True where the position is attendable.

    Causality is defined on absolute positions assuming aligned ends
    (query i attends keys j with j <= i + (nk - nq)), the standard
    convention for self-attention / decode.
    """
    qi = i0 + jnp.arange(bq)[:, None] + (nk - nq)
    kj = j0 + jnp.arange(bk)[None, :]
    return kj <= qi


# --------------------------------------------------------------------------
# Level-1 oracle: naive attention
# --------------------------------------------------------------------------


def naive_attention(q, k, v, cfg: QatConfig):
    """Materialised attention with the variant's fake quantization.

    Returns ``(o, o_prime, lse)``: the (fake-quantized-path) output, the
    high-precision-P output O' (Alg. 2 line 13), and the row logsumexp L.
    """
    nq, d = q.shape
    nk = k.shape[0]
    qf, kf, vf, dsq = preprocess_qkv(q, k, v, cfg)
    s = qf @ kf.T
    if dsq is not None:
        # ΔS fixup, computed per query tile in high precision.
        bq = cfg.block_q
        fix_rows = []
        for t in range(dsq.shape[0]):
            rows = min(bq, nq - t * bq)
            fix_rows.append(jnp.broadcast_to(dsq[t] @ kf.T, (rows, nk)))
        s = s + jnp.concatenate(fix_rows, axis=0)
    s = s / jnp.sqrt(jnp.float32(d))
    if cfg.causal:
        mask = _causal_mask(nq, nk, 0, 0, nq, nk)
        s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)  # unnormalised, rowmax == 1 — matches Alg. 1/2 P̃
    l = jnp.sum(p, axis=-1, keepdims=True)
    pf = quantize_p(p, cfg)
    o = (pf @ vf) / l
    o_prime = (p @ vf) / l
    lse = (m + jnp.log(l)).squeeze(-1)
    return o, o_prime, lse


# --------------------------------------------------------------------------
# Level-2 oracle: tiled flash forward (Algorithms 1 & 2)
# --------------------------------------------------------------------------


def flash_forward(q, k, v, cfg: QatConfig):
    """Tile-exact replica of Alg. 2 (training forward).

    Equals Alg. 1 (inference) when the O'/L outputs are ignored — the
    arithmetic on the O path is identical because FP4MM(Â, ŝ_A, B̂, ŝ_B)
    ≡ MM(φ⁻¹(φ(A)), φ⁻¹(φ(B))) with f32 accumulation (Eq. 6).

    A deliberate subtlety replicated from Alg. 1/2: ``P̃`` is fake-quantized
    **pre-normalisation** (its row maximum is exp(0) = 1), and ``l``
    accumulates the *unquantized* rowsum (line 11) while the O accumulator
    consumes the quantized ``P̃^F`` (line 12).
    """
    nq, d = q.shape
    nk = k.shape[0]
    bq, bk = cfg.block_q, cfg.block_k
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qf, kf, vf, dsq = preprocess_qkv(q, k, v, cfg)

    o_rows, op_rows, l_rows = [], [], []
    for ti, i0 in enumerate(range(0, nq, bq)):
        qi = qf[i0 : i0 + bq]
        m_i = jnp.full((qi.shape[0],), NEG_INF, jnp.float32)
        l_i = jnp.zeros((qi.shape[0],), jnp.float32)
        acc = jnp.zeros((qi.shape[0], d), jnp.float32)
        acc_hp = jnp.zeros((qi.shape[0], d), jnp.float32)
        for j0 in range(0, nk, bk):
            kj = kf[j0 : j0 + bk]
            vj = vf[j0 : j0 + bk]
            s = qi @ kj.T
            if dsq is not None:
                s = s + jnp.broadcast_to(dsq[ti] @ kj.T, s.shape)
            s = s * scale
            if cfg.causal:
                mask = _causal_mask(nq, nk, i0, j0, qi.shape[0], kj.shape[0])
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_i - m_new)
            p = jnp.exp(s - m_new[:, None])
            pf = quantize_p(p, cfg)
            l_i = alpha * l_i + jnp.sum(p, axis=-1)
            m_i = m_new
            acc = alpha[:, None] * acc + pf @ vj
            acc_hp = alpha[:, None] * acc_hp + p @ vj
        o_rows.append(acc / l_i[:, None])
        op_rows.append(acc_hp / l_i[:, None])
        l_rows.append(m_i + jnp.log(l_i))
    return (
        jnp.concatenate(o_rows, axis=0),
        jnp.concatenate(op_rows, axis=0),
        jnp.concatenate(l_rows, axis=0),
    )


# --------------------------------------------------------------------------
# Level-2 oracle: tiled flash backward (Algorithm 3)
# --------------------------------------------------------------------------


def flash_backward(q, k, v, o, o_prime, lse, do, cfg: QatConfig):
    """Tile-exact replica of Alg. 3 with the ablation switches.

    * ``cfg.high_prec_o``   — D = rowsum(dO ⊙ O′) vs rowsum(dO ⊙ O) (Fix B)
    * ``cfg.fq_p_bwd``      — fake-quant the recomputed P before dV (Fix A)
    * ``cfg.fq_inputs_bwd`` — recompute S from Q^F/K^F and propagate through
      V^F (True) vs raw Q/K/V (False; combined with the two flags above this
      is the "drop-in" stock-FA backward the paper shows explodes)

    Gradients are with respect to the *raw* q/k/v via the straight-through
    estimator (Eq. 7): dQ ≈ dQ^F etc.
    """
    nq, d = q.shape
    nk = k.shape[0]
    bq, bk = cfg.block_q, cfg.block_k
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    if cfg.fq_inputs_bwd:
        qb, kb, vb, dsq = preprocess_qkv(q, k, v, cfg)
    else:
        qb, kb, vb, dsq = q, k, v, None

    d_vec = jnp.sum(do * (o_prime if cfg.high_prec_o else o), axis=-1)  # Alg.3 l.3

    dq = jnp.zeros_like(qb)
    dk = jnp.zeros_like(kb)
    dv = jnp.zeros_like(vb)
    for j0 in range(0, nk, bk):
        kj = kb[j0 : j0 + bk]
        vj = vb[j0 : j0 + bk]
        dkj = jnp.zeros_like(kj)
        dvj = jnp.zeros_like(vj)
        for ti, i0 in enumerate(range(0, nq, bq)):
            qi = qb[i0 : i0 + bq]
            doi = do[i0 : i0 + bq]
            s = qi @ kj.T
            if dsq is not None:
                s = s + jnp.broadcast_to(dsq[ti] @ kj.T, s.shape)
            s = s * scale
            if cfg.causal:
                mask = _causal_mask(nq, nk, i0, j0, qi.shape[0], kj.shape[0])
                s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse[i0 : i0 + bq, None])  # normalised probabilities
            pf = quantize_p(p, cfg) if cfg.fq_p_bwd else p  # Alg.3 l.11 (Fix A)
            dvj = dvj + pf.T @ doi  # Alg.3 l.12
            dp = doi @ vj.T  # Alg.3 l.13
            ds = p * (dp - d_vec[i0 : i0 + bq, None]) * scale  # Alg.3 l.14 (hi-prec P)
            dq = dq.at[i0 : i0 + bq].add(ds @ kj)  # Alg.3 l.15
            dkj = dkj + ds.T @ qi  # Alg.3 l.16
        dk = dk.at[j0 : j0 + bk].add(dkj)
        dv = dv.at[j0 : j0 + bk].add(dvj)
    return dq, dk, dv


# --------------------------------------------------------------------------
# Autodiff oracle for the full QAT gradient (used to validate Alg. 3)
# --------------------------------------------------------------------------


def qat_loss_grads_autodiff(q, k, v, do, cfg: QatConfig):
    """Oracle gradients: differentiate <naive fake-quant attention, do>.

    Builds the *mathematical* function the STE pretends we differentiate:
    attention over fake-quantized inputs where every φ⁻¹(φ(·)) is replaced
    by identity in the backward (STE), with the probability fake-quant also
    handled by STE. Under exact arithmetic this equals Alg. 3 with both
    fixes enabled; pytest checks the match to fp tolerance.
    """

    def ste(x, axis):
        if not cfg.quantize:
            return x
        return x + jax.lax.stop_gradient(nvfp4.fake_quant(x, axis=axis) - x)

    def f(q, k, v):
        d = q.shape[-1]
        kk = k - jnp.mean(k, axis=0, keepdims=True) if cfg.smooth_k else k
        if cfg.smooth_k:
            # STE through the smoothing too: value path uses smoothed K,
            # gradient path is identity (matches Alg.3, which recomputes S
            # from the saved K^F and never differentiates the mean).
            kk = k + jax.lax.stop_gradient(kk - k)
        qf, kf, vf = ste(q, -1), ste(kk, -1), ste(v, 0)
        s = (qf @ kf.T) / jnp.sqrt(jnp.float32(d))
        if cfg.causal:
            mask = _causal_mask(q.shape[0], k.shape[0], 0, 0, q.shape[0], k.shape[0])
            s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        if cfg.quantize:
            if cfg.two_level_p:
                pf = p + jax.lax.stop_gradient(nvfp4.two_level_quant_p(p, axis=-1) - p)
            else:
                pf = ste(p, -1)
        else:
            pf = p
        return pf @ vf

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)
