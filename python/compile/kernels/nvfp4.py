"""NVFP4 / MXFP4 micro-scaling quantization (L1 numeric-format substrate).

Implements the block floating-point schemes the paper builds on (§2.1):

* **E2M1** — the FP4 element format: 1 sign / 2 exponent / 1 mantissa bits,
  15 distinct finite values ``±{0, .5, 1, 1.5, 2, 3, 4, 6}``.
* **E4M3** — the FP8 scale format used by NVFP4 (bias 7, max 448, finite).
* **E8M0** — the power-of-two scale format used by MXFP4.
* **NVFP4** — blocks of 16 contiguous elements along a chosen axis share one
  E4M3 scale ``s = amax/6`` (Eq. 1); elements are stored as E2M1 codes.
* **MXFP4** — blocks of 32 share one E8M0 scale (OCP MX spec v1.0).
* **two-level quantization** — SageAttention3's per-row rescale of the
  probability matrix ``P`` into ``[0, 448*6]`` before NVFP4 quantization.

All rounding is round-to-nearest with ties-to-even **on the code lattice**,
matching the hardware ``cvt.rn.satfinite.e2m1x2.f32`` semantics, and is
implemented with a vectorised midpoint-``searchsorted`` so the same exact
arithmetic runs inside Pallas kernels (interpret mode) and plain jnp.

The Rust side (``rust/src/formats``) re-implements these codecs bit-exactly;
``python/compile/gen_golden.py`` emits the golden vectors that pin the two
implementations together.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Lattices
# --------------------------------------------------------------------------

#: Non-negative representable E2M1 magnitudes, by code 0..7.
E2M1_VALUES = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], np.float32)
E2M1_MAX = 6.0

#: NVFP4 block size (elements sharing one E4M3 scale).
NVFP4_BLOCK = 16
#: MXFP4 block size (elements sharing one E8M0 scale).
MXFP4_BLOCK = 32

#: E4M3 (fp8e4m3fn) maximum finite value.
E4M3_MAX = 448.0
#: Two-level quantization target row maximum (SageAttention3): 448 * 6.
TWO_LEVEL_RMAX = E4M3_MAX * E2M1_MAX


def _e4m3_lattice() -> np.ndarray:
    """All non-negative finite E4M3 values in code order (codes 0x00..0x7E).

    value(code): exp = code>>3, man = code&7;
      exp == 0  -> man/8 * 2^-6                  (subnormals, incl. zero)
      exp >  0  -> (1 + man/8) * 2^(exp-7)
    Code 0x7F is NaN and excluded, so the lattice has 127 entries and is
    strictly increasing => lattice index == code, and index parity == the
    parity RNE tie-breaking needs.
    """
    vals = []
    for code in range(0x7F):
        exp = code >> 3
        man = code & 7
        if exp == 0:
            vals.append(man / 8.0 * 2.0 ** (-6))
        else:
            vals.append((1.0 + man / 8.0) * 2.0 ** (exp - 7))
    return np.array(vals, np.float32)


E4M3_VALUES = _e4m3_lattice()

# Midpoints used for RNE rounding. All are exactly representable in f32
# (they need one extra mantissa bit relative to the target format).
_E2M1_MID = ((E2M1_VALUES[1:] + E2M1_VALUES[:-1]) / 2.0).astype(np.float32)
_E4M3_MID = ((E4M3_VALUES[1:] + E4M3_VALUES[:-1]) / 2.0).astype(np.float32)


def _rne_binade(mag: jnp.ndarray, mant_bits: int, min_binade: int, max_val: float):
    """Round non-negative ``mag`` to a (sign-free) mini-float lattice, RNE.

    The lattice is "``mant_bits`` mantissa bits, normal binades ≥
    ``min_binade``, subnormal spacing below, saturate at ``max_val``".
    Closed form (no table captures — required inside Pallas kernels, and
    ~30× faster than a searchsorted lattice lookup):

        a = m·2^e (frexp, exact)  ⇒  binade b = e−1
        step = 2^(max(b, min_binade) − mant_bits)
        q = round_half_even(a / step) · step, clamped to max_val

    Half-way cases land exactly on ``.5`` multiples of ``step`` and
    ``jnp.round``'s banker's rounding picks the even quotient — which is
    precisely the even-mantissa-code convention of IEEE RNE (the pytest
    suite cross-checks this against an explicit lattice oracle).
    """
    _, e = jnp.frexp(mag)
    b = jnp.maximum(e - 1, min_binade)
    step = jnp.exp2((b - mant_bits).astype(jnp.float32))
    q = jnp.round(mag / step) * step
    return jnp.minimum(q, max_val)


def e2m1_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the nearest E2M1 value (signed, saturating at ±6, RNE)."""
    mag = _rne_binade(jnp.abs(x), mant_bits=1, min_binade=0, max_val=E2M1_MAX)
    return jnp.sign(x) * mag


def e2m1_code(x: jnp.ndarray) -> jnp.ndarray:
    """E2M1 4-bit code (sign<<3 | magnitude code) as uint8 — storage form."""
    mag = _rne_binade(jnp.abs(x), mant_bits=1, min_binade=0, max_val=E2M1_MAX)
    code = jnp.searchsorted(jnp.asarray(E2M1_VALUES), mag).astype(jnp.uint8)
    sign = (x < 0).astype(jnp.uint8)
    return (sign << 3) | code


def e4m3_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the nearest finite E4M3 value (signed, saturating, RNE)."""
    mag = _rne_binade(jnp.abs(x), mant_bits=3, min_binade=-6, max_val=E4M3_MAX)
    return jnp.sign(x) * mag


def _round_to_lattice_np(mag: np.ndarray, lattice: np.ndarray, mid: np.ndarray) -> np.ndarray:
    """Numpy lattice oracle for RNE rounding (tests + packed encoders).

    Double searchsorted over midpoints; exact midpoints pick the even
    lattice index (== even code). Saturates at the lattice maximum.
    """
    lo = np.searchsorted(mid, mag, side="left")
    hi = np.searchsorted(mid, mag, side="right")
    tie_even = np.where(lo % 2 == 0, lo, lo + 1)
    idx = np.where(lo == hi, lo, tie_even)
    idx = np.clip(idx, 0, len(lattice) - 1)
    return lattice[idx]


def e2m1_round_np(x: np.ndarray) -> np.ndarray:
    """Numpy lattice-oracle version of :func:`e2m1_round`."""
    x = np.asarray(x, np.float32)
    mag = _round_to_lattice_np(np.abs(x), E2M1_VALUES, _E2M1_MID)
    return (np.sign(x) * mag).astype(np.float32)


def e4m3_round_np(x: np.ndarray) -> np.ndarray:
    """Numpy lattice-oracle version of :func:`e4m3_round`."""
    x = np.asarray(x, np.float32)
    mag = _round_to_lattice_np(np.abs(x), E4M3_VALUES, _E4M3_MID)
    return (np.sign(x) * mag).astype(np.float32)


def e8m0_round_scale(amax: jnp.ndarray) -> jnp.ndarray:
    """MX E8M0 shared scale: 2^(floor(log2(amax)) - emax_elem), emax_elem=2.

    Per OCP MX v1.0 the shared scale for an e2m1 element format is the power
    of two that maps the block amax under the largest element exponent.
    amax == 0 maps to scale 1 (block is all zeros anyway).
    """
    safe = jnp.where(amax > 0, amax, 1.0)
    e = jnp.floor(jnp.log2(safe)) - 2.0
    e = jnp.clip(e, -127.0, 127.0)
    return jnp.where(amax > 0, jnp.exp2(e), 1.0)


# --------------------------------------------------------------------------
# Block quantization
# --------------------------------------------------------------------------


def _to_blocks(x: jnp.ndarray, block: int, axis: int):
    """Reshape ``x`` so ``axis`` is split into (n_blocks, block) trailing dims.

    Returns (blocked array with shape (..., n_blocks, block), inverse fn).
    """
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    shp = x.shape
    if shp[-1] % block != 0:
        raise ValueError(f"axis length {shp[-1]} not divisible by block {block}")
    xb = x.reshape(*shp[:-1], shp[-1] // block, block)

    def un_block(yb: jnp.ndarray) -> jnp.ndarray:
        y = yb.reshape(*shp)
        return jnp.moveaxis(y, -1, axis)

    return xb, un_block


def nvfp4_quant(x: jnp.ndarray, axis: int = -1, block: int = NVFP4_BLOCK):
    """NVFP4 quantization φ(X) (Eq. 1): per-block E4M3 scale + E2M1 codes.

    Returns ``(q, s)`` where ``q`` holds the *decoded* E2M1 values (shape of
    ``x``) and ``s`` the E4M3-rounded scales with shape
    ``x.shape`` with ``axis`` replaced by ``len/block``.
    Zero blocks get scale 1 so dequantization is exact.
    """
    xb, un_block = _to_blocks(x, block, axis)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    raw = amax / E2M1_MAX
    s = e4m3_round(raw)
    s = jnp.where(s > 0, s, 1.0)  # all-zero (or fully underflowed) blocks
    qb = e2m1_round(xb / s[..., None])
    return un_block(qb), s


def nvfp4_dequant(q: jnp.ndarray, s: jnp.ndarray, axis: int = -1, block: int = NVFP4_BLOCK):
    """φ⁻¹(X̂, s) (Eq. 2): multiply decoded codes by their block scale."""
    qb, un_block = _to_blocks(q, block, axis)
    return un_block(qb * s[..., None])


def mxfp4_quant(x: jnp.ndarray, axis: int = -1, block: int = MXFP4_BLOCK):
    """MXFP4 quantization: per-block E8M0 (power-of-two) scale + E2M1 codes."""
    xb, un_block = _to_blocks(x, block, axis)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    s = e8m0_round_scale(amax)
    qb = e2m1_round(xb / s[..., None])
    return un_block(qb), s


def fake_quant(x: jnp.ndarray, axis: int = -1, block: int = NVFP4_BLOCK) -> jnp.ndarray:
    """φ⁻¹(φ(X)) — the QAT fake-quantization operator (Eq. 6), no STE.

    Pure function of ``x``; gradients flow through the rounding (which is
    piecewise constant => zero almost everywhere). Use :func:`fake_quant_ste`
    inside training graphs.
    """
    q, s = nvfp4_quant(x, axis=axis, block=block)
    return nvfp4_dequant(q, s, axis=axis, block=block)


def fake_quant_ste(x: jnp.ndarray, axis: int = -1, block: int = NVFP4_BLOCK) -> jnp.ndarray:
    """Fake quantization with a straight-through estimator (Eq. 7).

    Forward value is ``fake_quant(x)``; the backward pass sees identity.
    """
    return x + jax.lax.stop_gradient(fake_quant(x, axis=axis, block=block) - x)


def two_level_quant_p(p: jnp.ndarray, axis: int = -1, block: int = NVFP4_BLOCK) -> jnp.ndarray:
    """SageAttention3 two-level fake quantization of the probability matrix.

    Each row of ``P`` (values in [0, 1], row = last axis before blocking is
    the key axis) is rescaled so its maximum hits ``448 * 6`` — the largest
    value an (E4M3 scale × E2M1 element) pair can express — then NVFP4
    fake-quantized, then scaled back. This recovers the dynamic range FP4
    would otherwise waste on [0, 1] inputs (§2.1).
    """
    rmax = jnp.max(p, axis=axis, keepdims=True)
    factor = jnp.where(rmax > 0, TWO_LEVEL_RMAX / rmax, 1.0)
    return fake_quant(p * factor, axis=axis, block=block) / factor


# --------------------------------------------------------------------------
# Packed storage helpers (build-time mirrors of rust/src/formats)
# --------------------------------------------------------------------------


def pack_e2m1(codes: np.ndarray) -> np.ndarray:
    """Pack uint8 4-bit E2M1 codes pairwise into bytes (low nibble first)."""
    flat = np.asarray(codes, np.uint8).reshape(-1)
    if flat.size % 2 != 0:
        flat = np.concatenate([flat, np.zeros(1, np.uint8)])
    return (flat[0::2] | (flat[1::2] << 4)).astype(np.uint8)


def unpack_e2m1(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_e2m1`; returns ``n`` 4-bit codes."""
    p = np.asarray(packed, np.uint8)
    lo = p & 0xF
    hi = p >> 4
    out = np.empty(p.size * 2, np.uint8)
    out[0::2] = lo
    out[1::2] = hi
    return out[:n]


def e2m1_decode_code(code: np.ndarray) -> np.ndarray:
    """Decode 4-bit E2M1 codes (sign<<3 | mag) to float32 values."""
    code = np.asarray(code, np.uint8)
    mag = E2M1_VALUES[code & 0x7]
    return np.where(code & 0x8, -mag, mag).astype(np.float32)


def e4m3_encode(x: np.ndarray) -> np.ndarray:
    """Encode f32 to the nearest E4M3 byte (sign<<7 | code), numpy-side."""
    x = np.asarray(x, np.float32)
    mag = _round_to_lattice_np(np.abs(x), E4M3_VALUES, _E4M3_MID)
    code = np.searchsorted(E4M3_VALUES, mag).astype(np.uint8)
    sign = (x < 0).astype(np.uint8)
    return (sign << 7) | code


def e4m3_decode(byte: np.ndarray) -> np.ndarray:
    """Decode E4M3 bytes (sign<<7 | code) to f32. Code 0x7F treated as NaN."""
    byte = np.asarray(byte, np.uint8)
    code = byte & 0x7F
    mag = np.where(code == 0x7F, np.nan, E4M3_VALUES[np.minimum(code, 0x7E)])
    return np.where(byte & 0x80, -mag, mag).astype(np.float32)
