"""L2 models: transformer LM and the diffusion-proxy rectified-flow model.

Both models route *all* attention through :mod:`compile.attention`, so the
precision variant (f32 / fp4 / qat / ablations — see ``ref.PRESETS``) is a
constructor argument and the rest of the network stays in high precision,
exactly as in the paper ("all non-attention components remain in high
precision", §3.1).

Parameters are a flat ``dict[str, Array]`` with **stacked per-layer
weights** (leading axis = layer) consumed by ``lax.scan``: the artifact
interface stays a fixed, small, ordered list of named tensors regardless of
depth, and the lowered HLO stays compact. Ordering = sorted key order —
mirrored by the Rust runtime via each artifact's metadata JSON.

Model sizes (DESIGN.md §2): byte-level vocab (V=256) LMs at tiny/small/base
plus a "large" (~110M) config for real hardware; diffusion-proxy models are
time-conditioned non-causal transformers over (frames × latent-dim) synthetic
video latents with a rectified-flow objective (Wan-2.1 stand-ins).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .attention import attention
from .kernels.ref import QatConfig, preset

# --------------------------------------------------------------------------
# Configs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LMConfig:
    """Decoder-only byte-level transformer."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    seq_len: int = 256
    mlp_mult: int = 4

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class DiffusionConfig:
    """Time-conditioned non-causal transformer over video latents."""

    latent_dim: int = 16
    frames: int = 32
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    mlp_mult: int = 4
    time_feats: int = 32  # sinusoidal time-embedding features

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


LM_SIZES = {
    # tiny: smoke tests + the pallas-impl train-step artifact
    "tiny": LMConfig(d_model=64, n_layers=2, n_heads=2, seq_len=64),
    # small: Table 2 / Table 4-"Qwen3-14B" stand-in (~1M params)
    "small": LMConfig(d_model=128, n_layers=4, n_heads=4, seq_len=256),
    # base: Table 4-"Llama-70B" stand-in (~6.5M params)
    "base": LMConfig(d_model=256, n_layers=8, n_heads=8, seq_len=256),
    # large: ~110M config for real hardware (not run by the CPU suite)
    "large": LMConfig(d_model=768, n_layers=12, n_heads=12, seq_len=512),
}

DIFF_SIZES = {
    "tiny": DiffusionConfig(d_model=64, n_layers=2, n_heads=2, frames=16),
    # small: Wan-2.1-1.3B stand-in (Table 2)
    "small": DiffusionConfig(d_model=128, n_layers=4, n_heads=4, frames=32),
    # base: Wan-2.1-14B stand-in (Table 1)
    "base": DiffusionConfig(d_model=256, n_layers=6, n_heads=8, frames=32),
}


# --------------------------------------------------------------------------
# Shared transformer block (stacked params + lax.scan)
# --------------------------------------------------------------------------


def _layer_norm(x, w, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


def block_param_shapes(d: int, mlp: int) -> dict:
    """Per-layer (unstacked) parameter shapes of one pre-LN block."""
    return {
        "ln1_w": (d,), "ln1_b": (d,),
        "wqkv": (d, 3 * d), "bqkv": (3 * d,),
        "wo": (d, d), "bo": (d,),
        "ln2_w": (d,), "ln2_b": (d,),
        "win": (d, mlp * d), "bin": (mlp * d,),
        "wout": (mlp * d, d), "bout": (d,),
    }


def _block(h, lp, n_heads: int, cfg: QatConfig, impl: str):
    """One pre-LN transformer block; ``lp`` holds this layer's params."""
    b, n, d = h.shape
    hd = d // n_heads
    x = _layer_norm(h, lp["ln1_w"], lp["ln1_b"])
    qkv = x @ lp["wqkv"] + lp["bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # (B, N, D) -> (B, H, N, hd)
        return t.reshape(b, n, n_heads, hd).transpose(0, 2, 1, 3)

    o = attention(heads(q), heads(k), heads(v), cfg, impl)  # the QAT hot-spot
    o = o.transpose(0, 2, 1, 3).reshape(b, n, d)
    h = h + o @ lp["wo"] + lp["bo"]
    x = _layer_norm(h, lp["ln2_w"], lp["ln2_b"])
    x = jax.nn.gelu(x @ lp["win"] + lp["bin"])
    return h + x @ lp["wout"] + lp["bout"]


def _scan_blocks(h, params, n_layers: int, n_heads: int, cfg: QatConfig, impl: str):
    block_keys = sorted(block_param_shapes(1, 1).keys())
    stacked = {k: params[k] for k in block_keys}

    def body(h, lp):
        return _block(h, lp, n_heads, cfg, impl), None

    h, _ = jax.lax.scan(body, h, stacked, length=n_layers)
    return h


# --------------------------------------------------------------------------
# Language model
# --------------------------------------------------------------------------


def lm_param_shapes(c: LMConfig) -> dict:
    """Flat name -> shape map (stacked blocks), the artifact interface."""
    d, mlp = c.d_model, c.mlp_mult
    shapes = {k: (c.n_layers,) + s for k, s in block_param_shapes(d, mlp).items()}
    shapes.update(
        tok_emb=(c.vocab, d),
        pos_emb=(c.seq_len, d),
        lnf_w=(d,), lnf_b=(d,),
        head=(d, c.vocab),
    )
    return shapes


def lm_init(c: LMConfig, seed: jnp.ndarray) -> dict:
    """GPT-2-style init, exported as its own artifact (seed -> params)."""
    shapes = lm_param_shapes(c)
    key = jax.random.PRNGKey(seed)
    params = {}
    for name in sorted(shapes):
        shp = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith(("_b", "bqkv", "bo", "bin", "bout")) or name in ("lnf_b",):
            params[name] = jnp.zeros(shp, jnp.float32)
        elif name.endswith("_w") or name in ("lnf_w",):
            params[name] = jnp.ones(shp, jnp.float32)
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
            std = 0.02 if name in ("tok_emb", "pos_emb") else 1.0 / jnp.sqrt(fan_in)
            params[name] = std * jax.random.normal(sub, shp, jnp.float32)
    # zero-init residual-out projections: stabilises deep-ish stacks
    params["wo"] = params["wo"] * 0.1
    params["wout"] = params["wout"] * 0.1
    return params


def lm_logits(params: dict, tokens: jnp.ndarray, c: LMConfig, cfg: QatConfig, impl: str):
    """Token logits. ``tokens (B, N) int32`` -> ``(B, N, V)``."""
    n = tokens.shape[1]
    h = params["tok_emb"][tokens] + params["pos_emb"][:n]
    h = _scan_blocks(h, params, c.n_layers, c.n_heads, cfg, impl)
    h = _layer_norm(h, params["lnf_w"], params["lnf_b"])
    return h @ params["head"]


def lm_loss(params, tokens, targets, loss_mask, c: LMConfig, cfg: QatConfig, impl: str):
    """Mean masked cross-entropy (f32 log-softmax).

    ``loss_mask`` weights each target position (1 = train on it); lets the
    same graph serve LM pretraining (all ones) and SFT (answer-only masks).
    """
    logits = lm_logits(params, tokens, c, cfg, impl)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).squeeze(-1)
    total = jnp.sum(nll * loss_mask)
    count = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return total / count


def lm_seq_nll(params, tokens, targets, loss_mask, c: LMConfig, cfg: QatConfig, impl: str):
    """Per-sequence (sum-NLL, token-count) — the eval-artifact core.

    Supports perplexity (mask = all ones) and multiple-choice scoring
    (mask = continuation region) with one compiled graph.
    """
    logits = lm_logits(params, tokens, c, cfg, impl)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).squeeze(-1)
    return jnp.sum(nll * loss_mask, axis=-1), jnp.sum(loss_mask, axis=-1)


# ---- Serving graphs (per-layer, weights as explicit inputs) ---------------
# The decode path splits the model so Rust can own the KV cache (NVFP4,
# paged) and run attention natively on quantized KV; see rust/src/serve.


def lm_embed_step(tok_emb, pos_emb, tokens, pos):
    """(B,) token + (B,) position -> (B, D) hidden."""
    return tok_emb[tokens] + pos_emb[pos]


def lm_layer_pre(h, ln1_w, ln1_b, wqkv, bqkv):
    """Pre-attention half of a block for one token: h (B, D) -> q,k,v (B, D)."""
    x = _layer_norm(h, ln1_w, ln1_b)
    qkv = x @ wqkv + bqkv
    q, k, v = jnp.split(qkv, 3, axis=-1)
    return q, k, v


def lm_layer_post(h, attn_out, wo, bo, ln2_w, ln2_b, win, bin_, wout, bout):
    """Post-attention half of a block for one token."""
    h = h + attn_out @ wo + bo
    x = _layer_norm(h, ln2_w, ln2_b)
    x = jax.nn.gelu(x @ win + bin_)
    return h + x @ wout + bout


def lm_head_step(h, lnf_w, lnf_b, head):
    """Final LN + unembedding for one token: (B, D) -> (B, V)."""
    return _layer_norm(h, lnf_w, lnf_b) @ head


# --------------------------------------------------------------------------
# Diffusion-proxy model (rectified flow over synthetic video latents)
# --------------------------------------------------------------------------


def diff_param_shapes(c: DiffusionConfig) -> dict:
    d, mlp = c.d_model, c.mlp_mult
    shapes = {k: (c.n_layers,) + s for k, s in block_param_shapes(d, mlp).items()}
    shapes.update(
        in_w=(c.latent_dim, d), in_b=(d,),
        t_w1=(2 * c.time_feats, d), t_b1=(d,),
        t_w2=(d, d), t_b2=(d,),
        pos_emb=(c.frames, d),
        lnf_w=(d,), lnf_b=(d,),
        out_w=(d, c.latent_dim), out_b=(c.latent_dim,),
    )
    return shapes


def diff_init(c: DiffusionConfig, seed: jnp.ndarray) -> dict:
    shapes = diff_param_shapes(c)
    key = jax.random.PRNGKey(seed)
    params = {}
    for name in sorted(shapes):
        shp = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("_b") or name in ("bqkv", "bo", "bin", "bout"):
            params[name] = jnp.zeros(shp, jnp.float32)
        elif name in ("ln1_w", "ln2_w", "lnf_w"):
            params[name] = jnp.ones(shp, jnp.float32)
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
            std = 0.02 if name == "pos_emb" else 1.0 / jnp.sqrt(fan_in)
            params[name] = std * jax.random.normal(sub, shp, jnp.float32)
    params["wo"] = params["wo"] * 0.1
    params["wout"] = params["wout"] * 0.1
    params["out_w"] = params["out_w"] * 0.1
    return params


def _time_embed(t: jnp.ndarray, feats: int):
    """Sinusoidal features of t ∈ [0, 1]: (B,) -> (B, 2·feats)."""
    freqs = jnp.exp(jnp.linspace(0.0, jnp.log(1000.0), feats))
    ang = t[:, None] * freqs[None, :] * jnp.pi
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def diff_velocity(params, x, t, c: DiffusionConfig, cfg: QatConfig, impl: str):
    """Velocity field v(x, t). ``x (B, T, Dl)``, ``t (B,)`` -> ``(B, T, Dl)``."""
    h = x @ params["in_w"] + params["in_b"] + params["pos_emb"][None, : x.shape[1]]
    te = _time_embed(t, (params["t_w1"].shape[0]) // 2)
    te = jax.nn.gelu(te @ params["t_w1"] + params["t_b1"])
    te = te @ params["t_w2"] + params["t_b2"]
    h = h + te[:, None, :]  # broadcast time conditioning over frames
    h = _scan_blocks(h, params, c.n_layers, c.n_heads, cfg, impl)
    h = _layer_norm(h, params["lnf_w"], params["lnf_b"])
    return h @ params["out_w"] + params["out_b"]


def diff_loss(params, x0, noise, t, c: DiffusionConfig, cfg: QatConfig, impl: str):
    """Rectified-flow matching loss (the Wan-2.1 objective, §B.1).

    ``x_t = (1−t)·x0 + t·x1`` with ``x1 = noise``; target velocity
    ``x1 − x0``; all randomness (noise, t) supplied by the Rust data
    pipeline so training is reproducible end to end.
    """
    t_b = t[:, None, None]
    xt = (1.0 - t_b) * x0 + t_b * noise
    v_target = noise - x0
    v_pred = diff_velocity(params, xt, t, c, cfg, impl)
    return jnp.mean((v_pred - v_target) ** 2)


def diff_sample_step(params, x, t, dt, c: DiffusionConfig, cfg: QatConfig, impl: str):
    """One Euler ODE step from noise (t=1) toward data (t=0): x ← x − dt·v."""
    v = diff_velocity(params, x, t, c, cfg, impl)
    return x - dt[:, None, None] * v


__all__ = [
    "LMConfig", "DiffusionConfig", "LM_SIZES", "DIFF_SIZES",
    "lm_param_shapes", "lm_init", "lm_logits", "lm_loss", "lm_seq_nll",
    "lm_embed_step", "lm_layer_pre", "lm_layer_post", "lm_head_step",
    "diff_param_shapes", "diff_init", "diff_velocity", "diff_loss",
    "diff_sample_step", "preset", "QatConfig",
]
