"""L2 training graphs: AdamW, gradient clipping, train/eval steps.

Every function here is lowered by ``aot.py`` into a self-contained HLO
artifact whose inputs/outputs are **flat, name-sorted tensor lists** (the
params dict flattens in sorted key order; optimizer state as ``m__<name>``
/ ``v__<name>``). The Rust coordinator threads the state through repeated
executions — python never runs at training time.

Hyperparameters that the paper sweeps or schedules (learning rate) enter as
scalar *inputs*; fixed ones (betas, weight decay, clip) are compile-time
constants mirroring Appendix B (AdamW β₁=0.9 β₂=0.999, wd=0.01, global-norm
clip 1.0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M
from .kernels.ref import QatConfig

BETA1, BETA2, EPS = 0.9, 0.999, 1e-8
WEIGHT_DECAY = 0.01
CLIP_NORM = 1.0


# --------------------------------------------------------------------------
# AdamW on flat dict params
# --------------------------------------------------------------------------


def _decay_mask(name: str) -> bool:
    """Apply weight decay to matrices only (skip LN scales, biases, embeds)."""
    if name.startswith(("ln", "lnf", "b", "t_b", "in_b", "out_b")):
        return False
    if name in ("tok_emb", "pos_emb"):
        return False
    return True


def adamw_init(params: dict) -> dict:
    """Zeroed first/second moments, keyed ``m__<name>`` / ``v__<name>``."""
    state = {}
    for k, p in params.items():
        state[f"m__{k}"] = jnp.zeros_like(p)
        state[f"v__{k}"] = jnp.zeros_like(p)
    return state


def global_norm(grads: dict) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads.values()))


def adamw_update(params: dict, grads: dict, opt: dict, step: jnp.ndarray, lr: jnp.ndarray):
    """One AdamW step with global-norm clipping.

    ``step`` is the 1-based iteration counter (f32 scalar, threaded through
    the artifact I/O); returns (new_params, new_opt, grad_norm_preclip).
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, CLIP_NORM / (gnorm + 1e-12))
    bc1 = 1.0 - BETA1**step
    bc2 = 1.0 - BETA2**step
    new_params, new_opt = {}, {}
    for k, p in params.items():
        g = grads[k] * scale
        m = BETA1 * opt[f"m__{k}"] + (1.0 - BETA1) * g
        v = BETA2 * opt[f"v__{k}"] + (1.0 - BETA2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + EPS)
        if _decay_mask(k):
            upd = upd + WEIGHT_DECAY * p
        new_params[k] = p - lr * upd
        new_opt[f"m__{k}"] = m
        new_opt[f"v__{k}"] = v
    return new_params, new_opt, gnorm


# --------------------------------------------------------------------------
# LM steps
# --------------------------------------------------------------------------


def lm_train_step(c: M.LMConfig, cfg: QatConfig, impl: str):
    """Build ``(params, opt, step, lr, tokens, loss_mask) -> (params', opt', loss, gnorm)``.

    ``tokens (B, N+1) int32``: position ``t`` predicts ``t+1``;
    ``loss_mask (B, N)``: 1 where the target participates in the loss
    (all-ones for continued pretraining, answer-spans for SFT — Table 3/4
    share this graph).
    """

    def step_fn(params, opt, step, lr, tokens, loss_mask):
        inp, tgt = tokens[:, :-1], tokens[:, 1:]

        def loss_fn(p):
            return M.lm_loss(p, inp, tgt, loss_mask, c, cfg, impl)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt, gnorm = adamw_update(params, grads, opt, step, lr)
        return new_params, new_opt, loss, gnorm

    return step_fn


def lm_eval_step(c: M.LMConfig, cfg: QatConfig, impl: str):
    """Build ``(params, tokens, loss_mask) -> (sum_nll (B,), n_tok (B,))``."""

    def eval_fn(params, tokens, loss_mask):
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        return M.lm_seq_nll(params, inp, tgt, loss_mask, c, cfg, impl)

    return eval_fn


# --------------------------------------------------------------------------
# Diffusion steps
# --------------------------------------------------------------------------


def diff_train_step(c: M.DiffusionConfig, cfg: QatConfig, impl: str):
    """Build ``(params, opt, step, lr, x0, noise, t) -> (params', opt', loss, gnorm)``."""

    def step_fn(params, opt, step, lr, x0, noise, t):
        def loss_fn(p):
            return M.diff_loss(p, x0, noise, t, c, cfg, impl)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt, gnorm = adamw_update(params, grads, opt, step, lr)
        return new_params, new_opt, loss, gnorm

    return step_fn


def diff_eval_step(c: M.DiffusionConfig, cfg: QatConfig, impl: str):
    """Build ``(params, x0, noise, t) -> loss`` (validation flow-matching loss)."""

    def eval_fn(params, x0, noise, t):
        return M.diff_loss(params, x0, noise, t, c, cfg, impl)

    return eval_fn


def diff_sampler_step(c: M.DiffusionConfig, cfg: QatConfig, impl: str):
    """Build ``(params, x, t, dt) -> x'`` — one Euler ODE step (Rust drives)."""

    def step_fn(params, x, t, dt):
        return M.diff_sample_step(params, x, t, dt, c, cfg, impl)

    return step_fn
