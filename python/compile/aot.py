"""AOT export: lower every artifact to HLO **text** + metadata JSON.

This is the single python↔rust interchange point. Each artifact is a jitted
function lowered once::

    lowered = jax.jit(fn).lower(*example_args)
    mlir    = lowered.compiler_ir("stablehlo")
    comp    = xla_client._xla.mlir.mlir_module_to_xla_computation(
                  str(mlir), use_tuple_args=False, return_tuple=True)
    text    = comp.as_hlo_text()

HLO *text* (not serialized HloModuleProto) is required: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (behind the
rust ``xla`` crate) rejects; the text parser reassigns ids and round-trips
cleanly. ``return_tuple=True`` ⇒ the rust side unwraps one tuple literal.

Artifacts are flat-tensor-list functions; ``<name>.meta.json`` records the
ordered input/output names+shapes+dtypes and the model/variant config so the
rust ``runtime::registry`` can bind them without any python at runtime.

Caching: each artifact embeds a hash of the compile-path sources; unchanged
artifacts are skipped (so ``make artifacts`` is a cheap no-op).

Usage: ``cd python && python -m compile.aot --out ../artifacts [--set core|all]
[--force] [--only NAME_SUBSTR]``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .attention import attention_fwd_full
from .kernels import nvfp4
from .kernels.ref import preset

F32 = jnp.float32
I32 = jnp.int32


def _sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _source_hash() -> str:
    """Hash of every compile-path source file (the cache key)."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in os.walk(root):
        for f in sorted(files):
            if f.endswith(".py") and f != "aot.py":
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# Flat wrappers: dict-param functions -> ordered tensor lists
# --------------------------------------------------------------------------


def _flatten_io(shapes: dict) -> list[str]:
    return sorted(shapes)


def _opt_names(pnames: list[str]) -> list[str]:
    return sorted([f"m__{n}" for n in pnames] + [f"v__{n}" for n in pnames])


class Spec:
    """One artifact: a flat function + named example inputs/outputs."""

    def __init__(self, name, fn, inputs, out_names, tags=(), extra_meta=None):
        self.name = name
        self.fn = fn
        self.inputs = inputs  # list[(name, ShapeDtypeStruct)]
        self.out_names = out_names
        self.tags = set(tags)
        self.extra_meta = extra_meta or {}


def _lm_batch_shape(c: M.LMConfig, batch: int):
    return (batch, c.seq_len + 1)


def lm_train_spec(size: str, variant: str, impl: str, batch: int, tags) -> Spec:
    c = M.LM_SIZES[size]
    bq = min(64, c.seq_len)
    cfg = preset(variant, causal=True, block_q=bq, block_k=bq)
    step = T.lm_train_step(c, cfg, impl)
    shapes = M.lm_param_shapes(c)
    pnames = _flatten_io(shapes)
    onames = _opt_names(pnames)

    def flat(*args):
        i = 0
        params = {n: a for n, a in zip(pnames, args[: len(pnames)])}
        i += len(pnames)
        opt = {n: a for n, a in zip(onames, args[i : i + len(onames)])}
        i += len(onames)
        stepc, lr, tokens, mask = args[i], args[i + 1], args[i + 2], args[i + 3]
        new_p, new_o, loss, gnorm = step(params, opt, stepc, lr, tokens, mask)
        return (
            tuple(new_p[n] for n in pnames)
            + tuple(new_o[n] for n in onames)
            + (loss, gnorm)
        )

    def opt_shape(n):
        return shapes[n.split("__", 1)[1]]

    inputs = (
        [(n, _sds(shapes[n])) for n in pnames]
        + [(n, _sds(opt_shape(n))) for n in onames]
        + [
            ("step", _sds((), F32)),
            ("lr", _sds((), F32)),
            ("tokens", _sds(_lm_batch_shape(c, batch), I32)),
            ("loss_mask", _sds((batch, c.seq_len), F32)),
        ]
    )
    out_names = pnames + onames + ["loss", "grad_norm"]
    suffix = "" if impl == "jnp" else f"_{impl}"
    return Spec(
        f"lm_train_{variant}{suffix}_{size}",
        flat,
        inputs,
        out_names,
        tags,
        {"kind": "lm_train", "size": size, "variant": variant, "impl": impl,
         "batch": batch, "model": c.__dict__, "param_names": pnames,
         "opt_names": onames},
    )


def lm_init_spec(size: str, tags) -> Spec:
    c = M.LM_SIZES[size]
    shapes = M.lm_param_shapes(c)
    pnames = _flatten_io(shapes)

    def flat(seed):
        p = M.lm_init(c, seed)
        return tuple(p[n] for n in pnames)

    return Spec(
        f"lm_init_{size}", flat, [("seed", _sds((), I32))], pnames, tags,
        {"kind": "lm_init", "size": size, "model": c.__dict__, "param_names": pnames},
    )


def lm_eval_spec(size: str, variant: str, impl: str, batch: int, tags) -> Spec:
    c = M.LM_SIZES[size]
    bq = min(64, c.seq_len)
    cfg = preset(variant, causal=True, block_q=bq, block_k=bq)
    ev = T.lm_eval_step(c, cfg, impl)
    shapes = M.lm_param_shapes(c)
    pnames = _flatten_io(shapes)

    def flat(*args):
        params = {n: a for n, a in zip(pnames, args[: len(pnames)])}
        tokens, mask = args[len(pnames)], args[len(pnames) + 1]
        return ev(params, tokens, mask)

    inputs = [(n, _sds(shapes[n])) for n in pnames] + [
        ("tokens", _sds(_lm_batch_shape(c, batch), I32)),
        ("loss_mask", _sds((batch, c.seq_len), F32)),
    ]
    return Spec(
        f"lm_eval_{variant}_{size}", flat, inputs, ["sum_nll", "n_tok"], tags,
        {"kind": "lm_eval", "size": size, "variant": variant, "impl": impl,
         "batch": batch, "model": c.__dict__, "param_names": pnames},
    )


def lm_serve_specs(size: str, batch: int, tags) -> list[Spec]:
    """Per-layer decode-step graphs; Rust owns attention + the FP4 KV cache."""
    c = M.LM_SIZES[size]
    d, mlp, v = c.d_model, c.mlp_mult * c.d_model, c.vocab
    specs = [
        Spec(
            f"lm_embed_{size}",
            M.lm_embed_step,
            [("tok_emb", _sds((v, d))), ("pos_emb", _sds((c.seq_len, d))),
             ("tokens", _sds((batch,), I32)), ("pos", _sds((batch,), I32))],
            ["h"], tags,
            {"kind": "lm_serve", "size": size, "stage": "embed", "batch": batch,
             "model": c.__dict__},
        ),
        Spec(
            f"lm_layer_pre_{size}",
            M.lm_layer_pre,
            [("h", _sds((batch, d))), ("ln1_w", _sds((d,))), ("ln1_b", _sds((d,))),
             ("wqkv", _sds((d, 3 * d))), ("bqkv", _sds((3 * d,)))],
            ["q", "k", "v"], tags,
            {"kind": "lm_serve", "size": size, "stage": "pre", "batch": batch},
        ),
        Spec(
            f"lm_layer_post_{size}",
            M.lm_layer_post,
            [("h", _sds((batch, d))), ("attn_out", _sds((batch, d))),
             ("wo", _sds((d, d))), ("bo", _sds((d,))),
             ("ln2_w", _sds((d,))), ("ln2_b", _sds((d,))),
             ("win", _sds((d, mlp))), ("bin", _sds((mlp,))),
             ("wout", _sds((mlp, d))), ("bout", _sds((d,)))],
            ["h"], tags,
            {"kind": "lm_serve", "size": size, "stage": "post", "batch": batch},
        ),
        Spec(
            f"lm_head_{size}",
            M.lm_head_step,
            [("h", _sds((batch, d))), ("lnf_w", _sds((d,))), ("lnf_b", _sds((d,))),
             ("head", _sds((d, v)))],
            ["logits"], tags,
            {"kind": "lm_serve", "size": size, "stage": "head", "batch": batch},
        ),
    ]
    return specs


def diff_init_spec(size: str, tags) -> Spec:
    c = M.DIFF_SIZES[size]
    shapes = M.diff_param_shapes(c)
    pnames = _flatten_io(shapes)

    def flat(seed):
        p = M.diff_init(c, seed)
        return tuple(p[n] for n in pnames)

    return Spec(
        f"diff_init_{size}", flat, [("seed", _sds((), I32))], pnames, tags,
        {"kind": "diff_init", "size": size, "model": c.__dict__, "param_names": pnames},
    )


def diff_train_spec(size: str, variant: str, impl: str, batch: int, tags) -> Spec:
    c = M.DIFF_SIZES[size]
    bq = min(16, c.frames)
    cfg = preset(variant, causal=False, block_q=bq, block_k=bq)
    step = T.diff_train_step(c, cfg, impl)
    shapes = M.diff_param_shapes(c)
    pnames = _flatten_io(shapes)
    onames = _opt_names(pnames)
    lat = (batch, c.frames, c.latent_dim)

    def flat(*args):
        i = len(pnames)
        params = {n: a for n, a in zip(pnames, args[:i])}
        opt = {n: a for n, a in zip(onames, args[i : i + len(onames)])}
        i += len(onames)
        stepc, lr, x0, noise, t = args[i : i + 5]
        new_p, new_o, loss, gnorm = step(params, opt, stepc, lr, x0, noise, t)
        return (
            tuple(new_p[n] for n in pnames)
            + tuple(new_o[n] for n in onames)
            + (loss, gnorm)
        )

    def opt_shape(n):
        return shapes[n.split("__", 1)[1]]

    inputs = (
        [(n, _sds(shapes[n])) for n in pnames]
        + [(n, _sds(opt_shape(n))) for n in onames]
        + [("step", _sds((), F32)), ("lr", _sds((), F32)),
           ("x0", _sds(lat)), ("noise", _sds(lat)), ("t", _sds((batch,)))]
    )
    out_names = pnames + onames + ["loss", "grad_norm"]
    return Spec(
        f"diff_train_{variant}_{size}", flat, inputs, out_names, tags,
        {"kind": "diff_train", "size": size, "variant": variant, "impl": impl,
         "batch": batch, "model": c.__dict__, "param_names": pnames,
         "opt_names": onames},
    )


def diff_eval_spec(size: str, variant: str, batch: int, tags) -> Spec:
    c = M.DIFF_SIZES[size]
    bq = min(16, c.frames)
    cfg = preset(variant, causal=False, block_q=bq, block_k=bq)
    ev = T.diff_eval_step(c, cfg, "jnp")
    shapes = M.diff_param_shapes(c)
    pnames = _flatten_io(shapes)
    lat = (batch, c.frames, c.latent_dim)

    def flat(*args):
        params = {n: a for n, a in zip(pnames, args[: len(pnames)])}
        x0, noise, t = args[len(pnames) :]
        return (ev(params, x0, noise, t),)

    inputs = [(n, _sds(shapes[n])) for n in pnames] + [
        ("x0", _sds(lat)), ("noise", _sds(lat)), ("t", _sds((batch,))),
    ]
    return Spec(
        f"diff_eval_{variant}_{size}", flat, inputs, ["loss"], tags,
        {"kind": "diff_eval", "size": size, "variant": variant, "batch": batch,
         "model": c.__dict__, "param_names": pnames},
    )


def diff_sample_spec(size: str, variant: str, batch: int, tags) -> Spec:
    c = M.DIFF_SIZES[size]
    bq = min(16, c.frames)
    cfg = preset(variant, causal=False, block_q=bq, block_k=bq)
    step = T.diff_sampler_step(c, cfg, "jnp")
    shapes = M.diff_param_shapes(c)
    pnames = _flatten_io(shapes)
    lat = (batch, c.frames, c.latent_dim)

    def flat(*args):
        params = {n: a for n, a in zip(pnames, args[: len(pnames)])}
        x, t, dt = args[len(pnames) :]
        return (step(params, x, t, dt),)

    inputs = [(n, _sds(shapes[n])) for n in pnames] + [
        ("x", _sds(lat)), ("t", _sds((batch,))), ("dt", _sds((batch,))),
    ]
    return Spec(
        f"diff_sample_{variant}_{size}", flat, inputs, ["x_next"], tags,
        {"kind": "diff_sample", "size": size, "variant": variant, "batch": batch,
         "model": c.__dict__, "param_names": pnames},
    )


def attn_spec(variant: str, impl: str, b: int, h: int, n: int, d: int, tags) -> Spec:
    """Kernel microbench artifact: (q, k, v) -> o (Figure 5 / Figure 4)."""
    bq = min(64, n)
    cfg = preset(variant, causal=False, block_q=bq, block_k=bq)

    def flat(q, k, v):
        o, _, _ = attention_fwd_full(q, k, v, cfg, impl=impl)
        return (o,)

    shape = (b, h, n, d)
    suffix = "" if impl == "jnp" else "_pallas"
    return Spec(
        f"attn_{variant}{suffix}_s{n}_d{d}",
        flat,
        [("q", _sds(shape)), ("k", _sds(shape)), ("v", _sds(shape))],
        ["o"], tags,
        {"kind": "attn_fwd", "variant": variant, "impl": impl,
         "b": b, "h": h, "n": n, "d": d,
         # analytical cost model inputs (perfmodel/):
         "flops_qk": 2 * b * h * n * n * d, "flops_pv": 2 * b * h * n * n * d},
    )


def quant_spec(n: int, d: int, impl: str, tags) -> Spec:
    """Standalone fake-quant artifact (Figure 4 cross-check vs rust formats)."""

    def flat_jnp(x):
        return (nvfp4.fake_quant(x, axis=-1),)

    def flat_pallas(x):
        from .kernels.attention_fwd import fake_quant_pallas

        return (fake_quant_pallas(x, axis=-1),)

    suffix = "" if impl == "jnp" else "_pallas"
    return Spec(
        f"quant_fake{suffix}_{n}x{d}",
        flat_jnp if impl == "jnp" else flat_pallas,
        [("x", _sds((n, d)))],
        ["xq"], tags,
        {"kind": "quant", "n": n, "d": d, "impl": impl},
    )


# --------------------------------------------------------------------------
# Manifest
# --------------------------------------------------------------------------


def build_manifest() -> list[Spec]:
    specs: list[Spec] = []
    core = ("core",)
    exp = ("exp",)
    bench = ("bench",)

    # --- LM ---------------------------------------------------------------
    for size, batch, tags in [("tiny", 2, core), ("small", 8, exp), ("base", 4, exp)]:
        specs.append(lm_init_spec(size, tags))
        for variant in ["f32", "qat"]:
            specs.append(lm_train_spec(size, variant, "jnp", batch, tags))
        for variant in ["f32", "fp4", "qat"]:
            specs.append(lm_eval_spec(size, variant, "jnp", batch, tags))
    # drop-in naive QAT (Fig. 3 naive baseline) on the small LM
    specs.append(lm_train_spec("small", "fp4", "jnp", 8, exp))
    # three-layer composition proof: pallas kernels inside a train step
    specs.append(lm_train_spec("tiny", "qat", "pallas", 2, core))
    # serving graphs (rust-native FP4-KV decode)
    specs += lm_serve_specs("tiny", 4, core)
    specs += lm_serve_specs("small", 4, exp)

    # --- Diffusion ----------------------------------------------------------
    diff_train_variants = [
        "f32", "qat", "fp4", "qat_smoothk", "qat_twolevel",
        "qat_no_o_prime", "qat_no_fq_p",
    ]
    for size, batch, tags in [("tiny", 4, core), ("small", 8, exp), ("base", 8, exp)]:
        specs.append(diff_init_spec(size, tags))
        variants = diff_train_variants if size != "tiny" else ["f32", "qat"]
        if size == "base":
            variants = ["f32", "qat"]  # Table 1 needs only these two trained
        for variant in variants:
            specs.append(diff_train_spec(size, variant, "jnp", batch, tags))
        for variant in ["f32", "fp4", "sage3", "qat_smoothk", "qat_twolevel"]:
            if size == "tiny" and variant not in ("f32", "fp4"):
                continue
            specs.append(diff_eval_spec(size, variant, batch, tags))
            specs.append(diff_sample_spec(size, variant, batch, tags))

    # --- Kernel benches (Fig. 5) + consistency (Fig. 4) --------------------
    for variant in ["f32", "fp4", "sage3"]:
        for n in [128, 256, 512, 1024]:
            for d in [64, 128]:
                specs.append(attn_spec(variant, "jnp", 1, 4, n, d, bench))
        specs.append(attn_spec(variant, "pallas", 1, 4, 256, 64, core + ("bench",)))
        specs.append(attn_spec(variant, "jnp", 1, 4, 256, 64, core))
    specs.append(quant_spec(1024, 64, "jnp", core))
    specs.append(quant_spec(1024, 64, "pallas", core))

    return specs


# --------------------------------------------------------------------------
# Golden vectors for the rust formats/attention cross-checks
# --------------------------------------------------------------------------


def write_golden(out_dir: str) -> None:
    """Deterministic golden vectors pinning rust/src/formats to this module."""
    rng = np.random.default_rng(20260710)
    x = np.concatenate(
        [
            rng.normal(0, 1, 256).astype(np.float32),
            rng.normal(0, 10, 128).astype(np.float32),
            rng.uniform(-6, 6, 64).astype(np.float32),
            np.array([0.0, -0.0, 0.25, -0.25, 0.75, 1.75, 2.5, 3.5, 5.0, 6.0,
                      7.0, -7.0, 448.0, 1e-4, -1e-4, 2688.0], np.float32),
        ]
    )
    e2 = np.asarray(nvfp4.e2m1_round(jnp.asarray(x)))
    e4 = np.asarray(nvfp4.e4m3_round(jnp.asarray(x)))
    blk = rng.normal(0, 2, (8, 32)).astype(np.float32)
    q, s = nvfp4.nvfp4_quant(jnp.asarray(blk), axis=-1)
    deq = nvfp4.nvfp4_dequant(q, s, axis=-1)
    qm, sm = nvfp4.mxfp4_quant(jnp.asarray(blk), axis=-1)
    golden = {
        "input": x.tolist(),
        "e2m1": e2.tolist(),
        "e4m3": e4.tolist(),
        "e4m3_codes": nvfp4.e4m3_encode(e4).tolist(),
        "block_input": blk.reshape(-1).tolist(),
        "block_rows": 8,
        "block_cols": 32,
        "nvfp4_q": np.asarray(q).reshape(-1).tolist(),
        "nvfp4_scale": np.asarray(s).reshape(-1).tolist(),
        "nvfp4_dequant": np.asarray(deq).reshape(-1).tolist(),
        "mxfp4_q": np.asarray(qm).reshape(-1).tolist(),
        "mxfp4_scale": np.asarray(sm).reshape(-1).tolist(),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "nvfp4_golden.json"), "w") as f:
        json.dump(golden, f)

    # Attention goldens: small cases per variant for the rust engine.
    from .kernels import ref as R

    cases = {}
    for variant in ["f32", "fp4", "sage3"]:
        for causal in [False, True]:
            if variant == "sage3" and causal:
                continue
            n, d = 32, 16
            q_ = rng.normal(0, 1, (n, d)).astype(np.float32)
            k_ = rng.normal(0, 1, (n, d)).astype(np.float32)
            v_ = rng.normal(0, 1, (n, d)).astype(np.float32)
            cfg = preset(variant, causal=causal, block_q=16, block_k=16)
            o, _, lse = R.naive_attention(
                jnp.asarray(q_), jnp.asarray(k_), jnp.asarray(v_), cfg
            )
            cases[f"{variant}_{'causal' if causal else 'full'}"] = {
                "n": n, "d": d,
                "q": q_.reshape(-1).tolist(),
                "k": k_.reshape(-1).tolist(),
                "v": v_.reshape(-1).tolist(),
                "o": np.asarray(o).reshape(-1).tolist(),
                "lse": np.asarray(lse).tolist(),
            }
    with open(os.path.join(out_dir, "attention_golden.json"), "w") as f:
        json.dump(cases, f)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def lower_spec(spec: Spec, out_dir: str, src_hash: str, force: bool) -> str:
    hlo_path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
    meta_path = os.path.join(out_dir, f"{spec.name}.meta.json")
    if not force and os.path.exists(hlo_path) and os.path.exists(meta_path):
        try:
            with open(meta_path) as f:
                if json.load(f).get("src_hash") == src_hash:
                    return "cached"
        except (json.JSONDecodeError, OSError):
            pass
    args = [s for _, s in spec.inputs]
    lowered = jax.jit(spec.fn).lower(*args)
    text = to_hlo_text(lowered)
    out_avals = jax.eval_shape(spec.fn, *args)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    meta = {
        "name": spec.name,
        "src_hash": src_hash,
        "inputs": [
            {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
            for n, s in spec.inputs
        ],
        "outputs": [
            {"name": n, "shape": list(o.shape), "dtype": str(o.dtype)}
            for n, o in zip(spec.out_names, out_avals)
        ],
        "tags": sorted(spec.tags),
        **spec.extra_meta,
    }
    with open(hlo_path, "w") as f:
        f.write(text)
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    return "built"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="all", choices=["core", "exp", "bench", "all"])
    ap.add_argument("--only", default=None, help="substring filter on names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    src_hash = _source_hash()
    specs = build_manifest()
    if args.set != "all":
        specs = [s for s in specs if args.set in s.tags]
    if args.only:
        specs = [s for s in specs if args.only in s.name]

    built = cached = 0
    for spec in specs:
        status = lower_spec(spec, args.out, src_hash, args.force)
        if status == "built":
            built += 1
            print(f"  built  {spec.name}", flush=True)
        else:
            cached += 1

    golden_dir = os.path.join(os.path.dirname(args.out), "rust", "tests", "golden")
    write_golden(golden_dir)
    # registry index for the rust side
    index = sorted(
        os.path.splitext(os.path.splitext(f)[0])[0]
        for f in os.listdir(args.out)
        if f.endswith(".meta.json")
    )
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump({"artifacts": index, "src_hash": src_hash}, f, indent=1)
    print(f"artifacts: {built} built, {cached} cached -> {args.out}")


if __name__ == "__main__":
    main()
