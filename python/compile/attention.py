"""L2 attention operator: `jax.custom_vjp` wiring Alg. 2 (fwd) to Alg. 3 (bwd).

Public entry point::

    o = attention(q, k, v, cfg, impl)   # q,k,v: (B, H, N, d)

Two interchangeable implementations, verified equivalent by pytest:

* ``impl="jnp"``  — the *fast* path: the same algorithms at whole-matrix
  tile granularity as fused batched einsums. Quantization placement is
  identical (φ on Q/K/V inputs, φ on the unnormalised P̃, high-precision O′,
  the D = rowsum(dO ⊙ O′) correction); only the online-softmax tiling is
  collapsed, which changes results by O(quantization noise) only. Used by
  the big training artifacts so the experiment suite is CPU-feasible.
* ``impl="pallas"`` — the L1 kernels (Alg. 1–3 tile-exact, interpret mode).
  Used by the kernel artifacts, consistency checks, and the tiny train-step
  smoke test, proving the full three-layer composition.

Gradients follow the straight-through estimator (Eq. 7): the backward
returns Alg. 3's dQ/dK/dV as the gradients of the *raw* inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import nvfp4
from .kernels.attention_bwd import flash_backward_pallas
from .kernels.attention_fwd import fake_quant_pallas, flash_forward_pallas
from .kernels.ref import NEG_INF, QatConfig, preset


def _flat(x):
    """(B, H, N, d) -> (B*H, N, d)."""
    b, h, n, d = x.shape
    return x.reshape(b * h, n, d)


def _mask(s, nq, nk):
    qpos = jnp.arange(nq)[:, None] + (nk - nq)
    kpos = jnp.arange(nk)[None, :]
    return jnp.where(kpos <= qpos, s, NEG_INF)


def _preprocess_batched(q, k, v, cfg: QatConfig):
    """Batched smoothing + input fake-quant ((BH, N, d) tensors).

    Mirrors ``ref.preprocess_qkv``; returns ``(qf, kf, vf, dsq)`` with
    ``dsq`` the (BH, Tq, d) per-tile q̄ means (sage3 smooth-Q fixup only).
    """
    dsq = None
    if cfg.smooth_k:
        k = k - jnp.mean(k, axis=1, keepdims=True)
    if cfg.smooth_q:
        bh, nq, d = q.shape
        bq = cfg.block_q
        qt = q.reshape(bh, nq // bq, bq, d)
        dsq = jnp.mean(qt, axis=2)  # (BH, Tq, d)
        q = (qt - dsq[:, :, None, :]).reshape(bh, nq, d)
    if cfg.quantize:
        q = nvfp4.fake_quant(q, axis=-1)
        k = nvfp4.fake_quant(k, axis=-1)
        v = nvfp4.fake_quant(v, axis=1)
    return q, k, v, dsq


def _quantize_p_batched(p, cfg: QatConfig):
    if not cfg.quantize:
        return p
    if cfg.two_level_p:
        return nvfp4.two_level_quant_p(p, axis=-1)
    return nvfp4.fake_quant(p, axis=-1)


# --------------------------------------------------------------------------
# Fast (jnp) forward / backward — whole-matrix tile granularity
# --------------------------------------------------------------------------


def _fwd_jnp(q, k, v, cfg: QatConfig):
    """Alg. 2 at full-matrix granularity. Returns (o, o', lse)."""
    _, nq, d = q.shape
    nk = k.shape[1]
    qf, kf, vf, dsq = _preprocess_batched(q, k, v, cfg)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf)
    if dsq is not None:
        fix = jnp.einsum("btd,bkd->btk", dsq, kf)  # high-precision ΔS
        s = s + jnp.repeat(fix, cfg.block_q, axis=1)
    s = s / jnp.sqrt(jnp.float32(d))
    if cfg.causal:
        s = _mask(s, nq, nk)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)  # unnormalised P̃, rowmax == 1 (Alg. 2 l.9)
    l = jnp.sum(p, axis=-1, keepdims=True)
    pf = _quantize_p_batched(p, cfg)  # Alg. 2 l.10
    o = jnp.einsum("bqk,bkd->bqd", pf, vf) / l  # quantized-P path (l.12)
    o_prime = jnp.einsum("bqk,bkd->bqd", p, vf) / l  # high-precision O' (l.13)
    lse = (m + jnp.log(l)).squeeze(-1)
    return o, o_prime, lse


def _bwd_jnp(q, k, v, o, o_prime, lse, do, cfg: QatConfig):
    """Alg. 3 at full-matrix granularity, with the ablation switches."""
    _, nq, d = q.shape
    nk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    if cfg.fq_inputs_bwd:
        qb, kb, vb, _ = _preprocess_batched(q, k, v, cfg)
    else:
        qb, kb, vb = q, k, v

    d_vec = jnp.sum(do * (o_prime if cfg.high_prec_o else o), axis=-1)  # l.3
    s = jnp.einsum("bqd,bkd->bqk", qb, kb) * scale  # l.9
    if cfg.causal:
        s = _mask(s, nq, nk)
    p = jnp.exp(s - lse[..., None])  # l.10 — normalised probabilities
    pf = _quantize_p_batched(p, cfg) if cfg.fq_p_bwd else p  # l.11 (Fix A)
    dv = jnp.einsum("bqk,bqd->bkd", pf, do)  # l.12
    dp = jnp.einsum("bqd,bkd->bqk", do, vb)  # l.13
    ds = p * (dp - d_vec[..., None]) * scale  # l.14 — high-precision P
    dq = jnp.einsum("bqk,bkd->bqd", ds, kb)  # l.15
    dk = jnp.einsum("bqk,bqd->bkd", ds, qb)  # l.16
    return dq, dk, dv


# --------------------------------------------------------------------------
# Pallas-backed forward / backward (tile-exact Alg. 1–3)
# --------------------------------------------------------------------------


def _fwd_pallas(q, k, v, cfg: QatConfig):
    qf, kf, vf, dsq = _preprocess_batched_pallas(q, k, v, cfg)
    return flash_forward_pallas(qf, kf, vf, cfg, dsq=dsq)


def _preprocess_batched_pallas(q, k, v, cfg: QatConfig):
    """Same as `_preprocess_batched` but the fake-quant runs as L1 kernels."""
    dsq = None
    if cfg.smooth_k:
        k = k - jnp.mean(k, axis=1, keepdims=True)
    if cfg.smooth_q:
        bh, nq, d = q.shape
        bq = cfg.block_q
        qt = q.reshape(bh, nq // bq, bq, d)
        dsq = jnp.mean(qt, axis=2)
        q = (qt - dsq[:, :, None, :]).reshape(bh, nq, d)
    if cfg.quantize:
        q = fake_quant_pallas(q, axis=-1)
        k = fake_quant_pallas(k, axis=-1)
        v = fake_quant_pallas(v, axis=1)
    return q, k, v, dsq


def _bwd_pallas(q, k, v, o, o_prime, lse, do, cfg: QatConfig):
    if cfg.fq_inputs_bwd:
        qb, kb, vb, _ = _preprocess_batched_pallas(q, k, v, cfg)
    else:
        qb, kb, vb = q, k, v
    return flash_backward_pallas(qb, kb, vb, o, o_prime, lse, do, cfg)


# --------------------------------------------------------------------------
# custom_vjp assembly
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _make_attention(cfg: QatConfig, impl: str):
    fwd_impl = _fwd_jnp if impl == "jnp" else _fwd_pallas
    bwd_impl = _bwd_jnp if impl == "jnp" else _bwd_pallas

    @jax.custom_vjp
    def attn(q, k, v):
        o, _, _ = fwd_impl(q, k, v, cfg)
        return o

    def attn_fwd(q, k, v):
        o, o_prime, lse = fwd_impl(q, k, v, cfg)
        # Residuals: raw q/k/v (bwd re-quantizes — mirrors the paper, which
        # stores Q^F/K^F/V^F; re-deriving them is value-identical and lets
        # the ablations flip `fq_inputs_bwd`), plus O, O', L.
        return o, (q, k, v, o, o_prime, lse)

    def attn_bwd(res, do):
        q, k, v, o, o_prime, lse = res
        dq, dk, dv = bwd_impl(q, k, v, o, o_prime, lse, do, cfg)
        return dq, dk, dv  # STE: gradients pass straight to the raw inputs

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def attention(q, k, v, cfg: QatConfig, impl: str = "jnp"):
    """Multi-head Attn-QAT attention. ``q,k,v: (B, H, N, d)`` → ``(B, H, N, d)``.

    ``cfg`` selects the variant (see ``ref.PRESETS``); ``impl`` selects the
    fast-jnp or Pallas execution path.
    """
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"unknown impl {impl!r}")
    b, h, n, d = q.shape
    attn = _make_attention(cfg, impl)
    o = attn(_flat(q), _flat(k), _flat(v))
    return o.reshape(b, h, n, d)


def attention_fwd_full(q, k, v, cfg: QatConfig, impl: str = "jnp"):
    """Forward returning (o, o_prime, lse) — for tests and kernel artifacts."""
    fwd_impl = _fwd_jnp if impl == "jnp" else _fwd_pallas
    b, h, n, d = q.shape
    o, op, lse = fwd_impl(_flat(q), _flat(k), _flat(v), cfg)
    return o.reshape(b, h, n, d), op.reshape(b, h, n, d), lse.reshape(b, h, n)


__all__ = ["attention", "attention_fwd_full", "QatConfig", "preset"]
