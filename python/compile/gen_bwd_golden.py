"""Backward golden vectors: pin `rust/src/qat/backward.rs` to the oracle.

Emits `rust/tests/golden/attention_bwd_golden.json`, the backward
counterpart of `aot.write_golden`'s attention cases. Each case carries the
inputs (q, k, v, do), the training-forward residuals (o, o_prime, lse) and
the oracle gradients (dq, dk, dv) for one ablation mode:

* ``qat_*``          — Attn-QAT backward: FP4 recomputation of S/P (Fix A)
                       + D from the high-precision O' (Fix B)
* ``dropin_*``       — "drop-in" stock-FA backward: f32 recomputation,
                       D from the quantized-path O
* ``qat_no_o_prime`` — Fix A only (Table 2 Exp. 7 ablation)
* ``qat_no_fq_p``    — Fix B only (Table 2 Exp. 8 ablation)
* ``f32_full``       — no quantization anywhere (FD-check baseline)

Gradients come from ``ref.flash_backward`` (the tile-exact Alg. 3 replica)
and are cross-checked here against ``attention_bwd.flash_backward_pallas``
— the two are pinned bit-for-bit by pytest, so either is "the oracle".

Run from the repo root:

    python -m python.compile.gen_bwd_golden
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from .kernels import ref as R
from .kernels.attention_bwd import flash_backward_pallas
from .kernels.ref import preset


class _Compact(float):
    """Float whose json repr is a pre-rendered shortest-roundtrip string."""

    def __new__(cls, text: str):
        self = super().__new__(cls, float(text))
        self.text = text if text not in ("", ".") else "0"
        return self

    def __repr__(self) -> str:
        return self.text


def _case(rng, name, variant, nq, nk, d, causal, outliers=False):
    cfg = preset(variant, causal=causal, block_q=16, block_k=16)
    q = rng.normal(0, 1, (nq, d)).astype(np.float32)
    k = rng.normal(0, 1, (nk, d)).astype(np.float32)
    v = rng.normal(0, 1, (nk, d)).astype(np.float32)
    do = rng.normal(0, 1, (nq, d)).astype(np.float32)
    if outliers:
        # Stress the E4M3 scale path / E2M1 saturation like the paper's
        # heavy-tailed activations.
        q[::7] *= 20.0
        k[::5] *= 50.0
        v[::3] *= 10.0
    # naive_attention, not flash_forward: the native Rust train forward
    # quantizes P̃ against the *global* row max (like `attend_fp4`, which
    # `attention_golden.json` pins to naive), while the tiled flash forward
    # quantizes per running tile max — same lattice only up to E4M3 scale
    # rounding. The backward itself renormalises via lse either way.
    o, o_prime, lse = R.naive_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), cfg)
    dq, dk, dv = R.flash_backward(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), o, o_prime, lse, jnp.asarray(do), cfg
    )

    # Cross-check vs the Pallas kernels (batched axis 0; fq_inputs handled
    # by the caller there, exactly as in the pytest parity suite). Best
    # effort: interpret-mode `pl.load` breaks on some jax versions; the two
    # implementations are already pinned bit-for-bit by pytest.
    if cfg.fq_inputs_bwd:
        qb, kb, vb, _ = R.preprocess_qkv(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), cfg)
    else:
        qb, kb, vb = jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)
    try:
        dq_p, dk_p, dv_p = flash_backward_pallas(
            qb[None], kb[None], vb[None], o[None], o_prime[None], lse[None],
            jnp.asarray(do)[None], cfg,
        )
        for a, b, which in [(dq, dq_p[0], "dq"), (dk, dk_p[0], "dk"), (dv, dv_p[0], "dv")]:
            err = float(jnp.max(jnp.abs(a - b)))
            assert err < 1e-4, f"{name}: ref vs pallas {which} diff {err}"
    except (AttributeError, TypeError) as e:  # pragma: no cover
        print(f"  [{name}] pallas cross-check skipped (interpret-mode incompat: {e})")

    def flat(x):
        # Shortest decimal that round-trips through f64 parse → f32 cast
        # back to the exact same f32 (keeps the golden file ~2.5× smaller
        # than the default float64-repr dump).
        return [
            _Compact(np.format_float_positional(v, unique=True, trim="0"))
            for v in np.asarray(x, np.float32).reshape(-1)
        ]

    return {
        "nq": nq,
        "nk": nk,
        "d": d,
        "causal": causal,
        "mode": variant,
        "q": flat(q),
        "k": flat(k),
        "v": flat(v),
        "do": flat(do),
        "o": flat(o),
        "o_prime": flat(o_prime),
        "lse": flat(lse),
        "dq": flat(dq),
        "dk": flat(dk),
        "dv": flat(dv),
    }


def main() -> None:
    rng = np.random.default_rng(20260726)
    cases = {
        "qat_full": _case(rng, "qat_full", "qat", 32, 32, 16, False),
        "qat_causal": _case(rng, "qat_causal", "qat", 32, 32, 16, True),
        "qat_outliers": _case(rng, "qat_outliers", "qat", 32, 32, 32, False, outliers=True),
        "qat_cross_causal": _case(rng, "qat_cross_causal", "qat", 32, 48, 16, True),
        "dropin_full": _case(rng, "dropin_full", "fp4", 32, 32, 16, False),
        "dropin_causal": _case(rng, "dropin_causal", "fp4", 32, 32, 16, True),
        "qat_no_o_prime": _case(rng, "qat_no_o_prime", "qat_no_o_prime", 32, 32, 16, True),
        "qat_no_fq_p": _case(rng, "qat_no_fq_p", "qat_no_fq_p", 32, 32, 16, True),
        "f32_full": _case(rng, "f32_full", "f32", 32, 32, 16, False),
    }
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "golden")
    out_dir = os.path.normpath(out_dir)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "attention_bwd_golden.json")
    with open(path, "w") as f:
        json.dump(cases, f)
    print(f"wrote {path} ({os.path.getsize(path)} bytes, {len(cases)} cases)")


if __name__ == "__main__":
    main()
