"""Attention kernel correctness: Pallas vs tile-exact refs vs naive oracle,
Alg. 3 gradients vs autodiff-STE, and the paper's ablation behaviours."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import attention as att
from compile.kernels import ref

F32 = np.float32


def rand_qkv(rng, b, h, n, d):
    return tuple(
        jnp.asarray(rng.normal(size=(b, h, n, d)).astype(F32)) for _ in range(3)
    )


# ---------------------------------------------------------------------------
# Forward: pallas == tile-exact flash ref; flash ≈ naive
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["f32", "fp4", "qat", "qat_twolevel", "sage3"])
@pytest.mark.parametrize("causal", [False, True])
def test_pallas_matches_flash_ref(variant, causal):
    if variant == "sage3" and causal:
        pytest.skip("sage3 is inference-only, non-causal in the paper")
    rng = np.random.default_rng(0)
    b, h, n, d = 1, 2, 64, 32
    q, k, v = rand_qkv(rng, b, h, n, d)
    cfg = ref.preset(variant, causal=causal, block_q=16, block_k=16)
    o_p, op_p, lse_p = att.attention_fwd_full(q, k, v, cfg, impl="pallas")
    for head in range(h):
        o_r, op_r, lse_r = ref.flash_forward(q[0, head], k[0, head], v[0, head], cfg)
        np.testing.assert_allclose(np.asarray(o_p[0, head]), np.asarray(o_r), atol=2e-6)
        np.testing.assert_allclose(np.asarray(op_p[0, head]), np.asarray(op_r), atol=2e-6)
        np.testing.assert_allclose(np.asarray(lse_p[0, head]), np.asarray(lse_r), atol=2e-6)


@pytest.mark.parametrize("variant", ["f32", "qat", "sage3"])
def test_flash_ref_close_to_naive(variant):
    # Tiled online-softmax quantization vs full-row quantization: equal for
    # f32, equal up to FP4 noise otherwise.
    rng = np.random.default_rng(1)
    n, d = 64, 32
    q = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    k = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    v = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    cfg = ref.preset(variant, block_q=16, block_k=16)
    o_f, _, lse_f = ref.flash_forward(q, k, v, cfg)
    o_n, _, lse_n = ref.naive_attention(q, k, v, cfg)
    tol = 1e-5 if variant == "f32" else 0.12
    assert float(jnp.max(jnp.abs(o_f - o_n))) < tol
    np.testing.assert_allclose(np.asarray(lse_f), np.asarray(lse_n), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([32, 64, 96]),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fast_jnp_fwd_matches_naive_hypothesis(n, d, causal, seed):
    # The fast batched path IS the naive oracle at full-matrix granularity.
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, 1, 1, n, d)
    cfg = ref.preset("qat", causal=causal, block_q=16, block_k=16)
    o, op, lse = att.attention_fwd_full(q, k, v, cfg, impl="jnp")
    o_n, op_n, lse_n = ref.naive_attention(q[0, 0], k[0, 0], v[0, 0], cfg)
    np.testing.assert_allclose(np.asarray(o[0, 0]), np.asarray(o_n), atol=3e-6)
    np.testing.assert_allclose(np.asarray(op[0, 0]), np.asarray(op_n), atol=3e-6)


def test_fwd_finite_with_extreme_inputs():
    # Outlier-heavy inputs (the paper's motivation) must not produce NaNs.
    rng = np.random.default_rng(2)
    n, d = 64, 32
    q = rng.normal(size=(1, 1, n, d)).astype(F32)
    q[0, 0, 3, :] *= 100.0  # token outlier
    q = jnp.asarray(q)
    k = jnp.asarray(rng.normal(size=(1, 1, n, d)).astype(F32) * 50.0)
    v = jnp.asarray(rng.normal(size=(1, 1, n, d)).astype(F32))
    for variant in ["fp4", "sage3"]:
        cfg = ref.preset(variant, block_q=16, block_k=16)
        o, _, _ = att.attention_fwd_full(q, k, v, cfg, impl="jnp")
        assert bool(jnp.all(jnp.isfinite(o))), variant


# ---------------------------------------------------------------------------
# Backward: Alg. 3 vs autodiff-STE oracle; pallas bwd vs ref bwd
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["f32", "qat", "qat_smoothk"])
@pytest.mark.parametrize("causal", [False, True])
def test_alg3_matches_autodiff_ste(variant, causal):
    rng = np.random.default_rng(3)
    n, d = 64, 32
    q = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    k = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    v = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    do = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    cfg = ref.preset(variant, causal=causal, block_q=16, block_k=16)
    o, op, lse = ref.naive_attention(q, k, v, cfg)
    dq, dk, dv = ref.flash_backward(q, k, v, o, op, lse, do, cfg)
    dq2, dk2, dv2 = ref.qat_loss_grads_autodiff(q, k, v, do, cfg)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dq2), atol=3e-5)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk2), atol=3e-5)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv2), atol=3e-5)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_custom_vjp_grads_match_ref_bwd(impl):
    rng = np.random.default_rng(4)
    b, h, n, d = 1, 2, 64, 32
    q, k, v = rand_qkv(rng, b, h, n, d)
    do = jnp.asarray(rng.normal(size=(h, n, d)).astype(F32))
    cfg = ref.preset("qat", causal=True, block_q=16, block_k=16)
    attn = att._make_attention(cfg, impl)
    _, vjp = jax.vjp(attn, q[0], k[0], v[0])
    dq, dk, dv = vjp(do)
    for head in range(h):
        o_r, op_r, lse_r = ref.flash_forward(q[0, head], k[0, head], v[0, head], cfg)
        dq_r, dk_r, dv_r = ref.flash_backward(
            q[0, head], k[0, head], v[0, head], o_r, op_r, lse_r, do[head], cfg
        )
        np.testing.assert_allclose(np.asarray(dq[head]), np.asarray(dq_r), atol=5e-5)
        np.testing.assert_allclose(np.asarray(dk[head]), np.asarray(dk_r), atol=5e-5)
        np.testing.assert_allclose(np.asarray(dv[head]), np.asarray(dv_r), atol=5e-5)


def test_ablation_no_o_prime_changes_gradients():
    # Exp. 7: dropping O' changes dQ/dK (the D term) but leaves dV intact.
    rng = np.random.default_rng(5)
    n, d = 64, 32
    q = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    k = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    v = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    do = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    good = ref.preset("qat", block_q=16, block_k=16)
    bad = ref.preset("qat_no_o_prime", block_q=16, block_k=16)
    o, op, lse = ref.naive_attention(q, k, v, good)
    dq_g, dk_g, dv_g = ref.flash_backward(q, k, v, o, op, lse, do, good)
    dq_b, dk_b, dv_b = ref.flash_backward(q, k, v, o, op, lse, do, bad)
    assert float(jnp.max(jnp.abs(dq_g - dq_b))) > 1e-5
    assert float(jnp.max(jnp.abs(dk_g - dk_b))) > 1e-5
    np.testing.assert_allclose(np.asarray(dv_g), np.asarray(dv_b), atol=1e-7)


def test_ablation_no_fq_p_changes_dv_only():
    # Exp. 8: un-quantized P in bwd perturbs dV (and only dV).
    rng = np.random.default_rng(6)
    n, d = 64, 32
    q = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    k = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    v = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    do = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    good = ref.preset("qat", block_q=16, block_k=16)
    bad = ref.preset("qat_no_fq_p", block_q=16, block_k=16)
    o, op, lse = ref.naive_attention(q, k, v, good)
    dq_g, dk_g, dv_g = ref.flash_backward(q, k, v, o, op, lse, do, good)
    dq_b, dk_b, dv_b = ref.flash_backward(q, k, v, o, op, lse, do, bad)
    np.testing.assert_allclose(np.asarray(dq_g), np.asarray(dq_b), atol=1e-7)
    np.testing.assert_allclose(np.asarray(dk_g), np.asarray(dk_b), atol=1e-7)
    assert float(jnp.max(jnp.abs(dv_g - dv_b))) > 1e-5


def test_smooth_k_invariant_to_common_offset():
    # The whole point of K smoothing: a shared K offset must (nearly)
    # vanish before quantization.
    rng = np.random.default_rng(7)
    n, d = 64, 32
    q = jnp.asarray(rng.normal(size=(1, 1, n, d)).astype(F32))
    k0 = rng.normal(size=(1, 1, n, d)).astype(F32)
    v = jnp.asarray(rng.normal(size=(1, 1, n, d)).astype(F32))
    cfg = ref.preset("qat_smoothk", block_q=16, block_k=16)
    o_base, _, _ = att.attention_fwd_full(q, jnp.asarray(k0), v, cfg, impl="jnp")
    o_off, _, _ = att.attention_fwd_full(q, jnp.asarray(k0 + 7.0), v, cfg, impl="jnp")
    np.testing.assert_allclose(np.asarray(o_base), np.asarray(o_off), atol=1e-5)


def test_f32_variant_matches_plain_softmax_autodiff():
    # With quantization off, the custom_vjp must equal jax's own gradient.
    rng = np.random.default_rng(8)
    b, h, n, d = 1, 1, 32, 16
    q, k, v = rand_qkv(rng, b, h, n, d)
    cfg = ref.preset("f32", causal=True, block_q=16, block_k=16)

    def plain(q, k, v):
        s = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(d))
        mask = jnp.tril(jnp.ones((n, n), bool))
        s = jnp.where(mask, s, ref.NEG_INF)
        return jnp.einsum("hqk,hkd->hqd", jax.nn.softmax(s, axis=-1), v)

    attn = att._make_attention(cfg, "jnp")
    do = jnp.asarray(rng.normal(size=(h, n, d)).astype(F32))
    _, vjp_c = jax.vjp(attn, q[0], k[0], v[0])
    _, vjp_p = jax.vjp(plain, q[0], k[0], v[0])
    for g_c, g_p in zip(vjp_c(do), vjp_p(do)):
        np.testing.assert_allclose(np.asarray(g_c), np.asarray(g_p), atol=1e-5)
