"""Quantizer unit + property tests: the E2M1/E4M3/E8M0 codecs and the
NVFP4/MXFP4 block schemes (hypothesis sweeps per the repo test policy)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import nvfp4

F32 = np.float32


# ---------------------------------------------------------------------------
# E2M1
# ---------------------------------------------------------------------------


def test_e2m1_lattice_fixed_points():
    for v in nvfp4.E2M1_VALUES:
        for s in (1.0, -1.0):
            assert float(nvfp4.e2m1_round(jnp.float32(s * v))) == s * v


def test_e2m1_saturation():
    assert float(nvfp4.e2m1_round(jnp.float32(100.0))) == 6.0
    assert float(nvfp4.e2m1_round(jnp.float32(-100.0))) == -6.0


@pytest.mark.parametrize(
    "x,want",
    [(0.25, 0.0), (0.75, 1.0), (1.25, 1.0), (1.75, 2.0), (2.5, 2.0), (3.5, 4.0), (5.0, 4.0)],
)
def test_e2m1_ties_to_even(x, want):
    assert float(nvfp4.e2m1_round(jnp.float32(x))) == want
    assert float(nvfp4.e2m1_round(jnp.float32(-x))) == -want


@settings(max_examples=300, deadline=None)
@given(st.floats(-20, 20, allow_nan=False, width=32))
def test_e2m1_matches_lattice_oracle(x):
    got = float(nvfp4.e2m1_round(jnp.float32(x)))
    want = float(nvfp4.e2m1_round_np(np.float32(x)))
    assert got == want


@settings(max_examples=200, deadline=None)
@given(st.floats(-8, 8, allow_nan=False, width=32))
def test_e2m1_is_nearest(x):
    got = float(nvfp4.e2m1_round(jnp.float32(x)))
    lattice = np.concatenate([nvfp4.E2M1_VALUES, -nvfp4.E2M1_VALUES])
    best = lattice[np.argmin(np.abs(lattice - x))]
    assert abs(got - x) <= abs(best - x) + 1e-7


# ---------------------------------------------------------------------------
# E4M3
# ---------------------------------------------------------------------------


def test_e4m3_code_table_roundtrip():
    vals = nvfp4.E4M3_VALUES
    assert len(vals) == 127
    assert vals[-1] == 448.0
    codes = nvfp4.e4m3_encode(vals)
    assert np.array_equal(nvfp4.e4m3_decode(codes), vals)


@settings(max_examples=300, deadline=None)
@given(st.floats(-500, 500, allow_nan=False, width=32))
def test_e4m3_matches_lattice_oracle(x):
    got = float(nvfp4.e4m3_round(jnp.float32(x)))
    want = float(nvfp4.e4m3_round_np(np.float32(x)))
    assert got == want


def test_e4m3_subnormals():
    # min subnormal 2^-9
    assert float(nvfp4.e4m3_round(jnp.float32(0.001953125))) == 0.001953125
    # below half of min subnormal -> 0
    assert float(nvfp4.e4m3_round(jnp.float32(0.0009))) == 0.0


# ---------------------------------------------------------------------------
# Block quantization
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(
    st.integers(1, 6).map(lambda b: b * 16),
    st.integers(0, 2**31 - 1),
    st.floats(0.1, 50.0),  # normal-range E4M3 scales (see subnormal test)
)
def test_nvfp4_roundtrip_properties(cols, seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, (4, cols)).astype(F32))
    q, s = nvfp4.nvfp4_quant(x, axis=-1)
    deq = nvfp4.nvfp4_dequant(q, s, axis=-1)
    # fake_quant == quant->dequant
    fq = nvfp4.fake_quant(x, axis=-1)
    assert np.array_equal(np.asarray(fq), np.asarray(deq))
    # idempotent (holds when scales stay in E4M3's normal range, where the
    # scale rounding error <= 6.25% keeps amax/s inside [5.6, 6.4] -> the
    # amax element re-rounds to exactly 6s and the scale is a fixed point)
    assert np.array_equal(np.asarray(nvfp4.fake_quant(fq, axis=-1)), np.asarray(fq))
    # codes bounded
    assert np.all(np.abs(np.asarray(q)) <= 6.0)
    # scales positive
    assert np.all(np.asarray(s) > 0)
    # elementwise error bound: half the widest E2M1 gap (|4..6| -> 1.0) per
    # unit scale, inflated by the worst normal-range E4M3 scale error.
    err = np.abs(np.asarray(fq) - np.asarray(x))
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    assert np.all(err <= 1.07 * amax / 6.0 + 1e-6)


def test_nvfp4_subnormal_scales_not_idempotent_but_bounded():
    """With block amax below ~6·2⁻⁶ the E4M3 scale lands in its subnormal
    range where relative rounding error reaches ~25%: fake-quant is then NOT
    a projection (real NVFP4 behaves identically). Error must still be
    bounded by the coarser effective step."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.01, (8, 32)).astype(F32))
    fq = np.asarray(nvfp4.fake_quant(x, axis=-1))
    err = np.abs(fq - np.asarray(x))
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    assert np.all(err <= 1.6 * amax / 6.0 + 1e-7)


def test_zero_block_exact():
    x = jnp.zeros((2, 32), F32)
    assert np.array_equal(np.asarray(nvfp4.fake_quant(x)), np.zeros((2, 32), F32))


def test_quant_axis_selection():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(32, 8)).astype(F32))
    fq0 = nvfp4.fake_quant(x, axis=0)
    fq0t = nvfp4.fake_quant(x.T, axis=-1).T
    assert np.allclose(np.asarray(fq0), np.asarray(fq0t))


def test_scale_invariance_pow2():
    # Scaling inputs by powers of two scales outputs exactly (scales are
    # e4m3 with wide exponent range).
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 16)).astype(F32))
    a = np.asarray(nvfp4.fake_quant(x)) * 4.0
    b = np.asarray(nvfp4.fake_quant(x * 4.0))
    assert np.allclose(a, b)


def test_mxfp4_pow2_scales():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 3, (2, 64)).astype(F32))
    q, s = nvfp4.mxfp4_quant(x, axis=-1)
    log2s = np.log2(np.asarray(s))
    assert np.allclose(log2s, np.round(log2s))


def test_two_level_p_beats_plain_on_probabilities():
    # For softmax-like rows, two-level quantization should reduce error.
    rng = np.random.default_rng(3)
    logits = rng.normal(0, 2, (16, 64)).astype(F32)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = (p / p.sum(-1, keepdims=True)).astype(F32)
    pj = jnp.asarray(p)
    err_plain = np.abs(np.asarray(nvfp4.fake_quant(pj, axis=-1)) - p).mean()
    err_two = np.abs(np.asarray(nvfp4.two_level_quant_p(pj, axis=-1)) - p).mean()
    assert err_two < err_plain


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(4)
    x = rng.normal(0, 2, 64).astype(F32)
    codes = np.asarray(nvfp4.e2m1_code(jnp.asarray(x)))
    packed = nvfp4.pack_e2m1(codes)
    assert packed.nbytes == 32
    assert np.array_equal(nvfp4.unpack_e2m1(packed, 64), codes)
    decoded = nvfp4.e2m1_decode_code(codes)
    assert np.array_equal(decoded, np.asarray(nvfp4.e2m1_round(jnp.asarray(x))))
