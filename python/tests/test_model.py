"""Model/train-graph tests: shapes, init statistics, loss behaviour under a
few optimizer steps, masking semantics, sampler step, serve-path equality."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile import train as T
from compile.kernels.ref import preset

F32 = np.float32


@pytest.fixture(scope="module")
def lm_cfg():
    return M.LM_SIZES["tiny"]


@pytest.fixture(scope="module")
def lm_params(lm_cfg):
    return M.lm_init(lm_cfg, jnp.int32(0))


def test_lm_param_shapes_match_init(lm_cfg, lm_params):
    shapes = M.lm_param_shapes(lm_cfg)
    assert set(shapes) == set(lm_params)
    for k, s in shapes.items():
        assert lm_params[k].shape == s, k


def test_lm_init_statistics(lm_cfg, lm_params):
    # LN scales at 1, biases at 0, matrices roughly fan-in scaled.
    assert np.allclose(np.asarray(lm_params["ln1_w"]), 1.0)
    assert np.allclose(np.asarray(lm_params["bqkv"]), 0.0)
    wqkv = np.asarray(lm_params["wqkv"])
    assert abs(wqkv.std() - 1.0 / np.sqrt(lm_cfg.d_model)) < 0.02


def test_lm_logits_shape_and_initial_loss(lm_cfg, lm_params):
    rng = np.random.default_rng(0)
    b, n = 2, lm_cfg.seq_len
    tokens = jnp.asarray(rng.integers(0, 256, (b, n)), jnp.int32)
    cfg = preset("f32", causal=True, block_q=32, block_k=32)
    logits = M.lm_logits(lm_params, tokens, lm_cfg, cfg, "jnp")
    assert logits.shape == (b, n, lm_cfg.vocab)
    mask = jnp.ones((b, n - 1), jnp.float32)
    loss = M.lm_loss(lm_params, tokens[:, :-1], tokens[:, 1:], mask, lm_cfg, cfg, "jnp")
    # Fresh init ≈ uniform over 256 bytes.
    assert abs(float(loss) - np.log(256)) < 0.5


@pytest.mark.parametrize("variant", ["f32", "qat"])
def test_lm_train_step_decreases_loss(lm_cfg, variant):
    params = M.lm_init(lm_cfg, jnp.int32(1))
    cfg = preset(variant, causal=True, block_q=32, block_k=32)
    step_fn = jax.jit(T.lm_train_step(lm_cfg, cfg, "jnp"))
    opt = T.adamw_init(params)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(97, 105, (4, lm_cfg.seq_len + 1)), jnp.int32)
    mask = jnp.ones((4, lm_cfg.seq_len), jnp.float32)
    losses = []
    for i in range(8):
        params, opt, loss, gnorm = step_fn(
            params, opt, jnp.float32(i + 1), jnp.float32(3e-3), tokens, mask
        )
        assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses  # memorises the fixed batch


def test_loss_mask_zeroes_contributions(lm_cfg, lm_params):
    rng = np.random.default_rng(2)
    b, n = 2, lm_cfg.seq_len
    tokens = jnp.asarray(rng.integers(0, 256, (b, n + 1)), jnp.int32)
    cfg = preset("f32", causal=True, block_q=32, block_k=32)
    ev = T.lm_eval_step(lm_cfg, cfg, "jnp")
    full_nll, full_cnt = ev(lm_params, tokens, jnp.ones((b, n), jnp.float32))
    half_mask = jnp.concatenate(
        [jnp.ones((b, n // 2)), jnp.zeros((b, n - n // 2))], axis=1
    ).astype(jnp.float32)
    half_nll, half_cnt = ev(lm_params, tokens, half_mask)
    assert np.all(np.asarray(half_cnt) == n // 2)
    assert np.all(np.asarray(half_nll) < np.asarray(full_nll))


def test_adamw_decay_mask():
    assert T._decay_mask("wqkv")
    assert T._decay_mask("head")
    assert not T._decay_mask("ln1_w")
    assert not T._decay_mask("bqkv")
    assert not T._decay_mask("tok_emb")


def test_grad_clip_bounds_update():
    # A pathological gradient must be clipped to CLIP_NORM.
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = T.adamw_init(params)
    grads = {"w": jnp.full((4,), 1e6, jnp.float32)}
    new_p, _, gnorm = T.adamw_update(params, grads, opt, jnp.float32(1), jnp.float32(0.1))
    assert float(gnorm) > 1e6  # reported pre-clip
    assert float(jnp.max(jnp.abs(new_p["w"] - params["w"]))) < 0.5


# ---------------------------------------------------------------------------
# Diffusion model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def diff_cfg():
    return M.DIFF_SIZES["tiny"]


def test_diff_shapes_and_loss(diff_cfg):
    params = M.diff_init(diff_cfg, jnp.int32(0))
    rng = np.random.default_rng(3)
    b = 3
    x0 = jnp.asarray(rng.normal(size=(b, diff_cfg.frames, diff_cfg.latent_dim)).astype(F32))
    noise = jnp.asarray(rng.normal(size=x0.shape).astype(F32))
    t = jnp.asarray(rng.uniform(size=(b,)).astype(F32))
    cfg = preset("f32", block_q=16, block_k=16)
    v = M.diff_velocity(params, x0, t, diff_cfg, cfg, "jnp")
    assert v.shape == x0.shape
    loss = M.diff_loss(params, x0, noise, t, diff_cfg, cfg, "jnp")
    assert np.isfinite(float(loss))


def test_diff_train_step_decreases_loss(diff_cfg):
    params = M.diff_init(diff_cfg, jnp.int32(1))
    cfg = preset("qat", block_q=16, block_k=16)
    step_fn = jax.jit(T.diff_train_step(diff_cfg, cfg, "jnp"))
    opt = T.adamw_init(params)
    rng = np.random.default_rng(4)
    b = 4
    x0 = jnp.asarray(rng.normal(size=(b, diff_cfg.frames, diff_cfg.latent_dim)).astype(F32))
    noise = jnp.asarray(rng.normal(size=x0.shape).astype(F32))
    t = jnp.asarray(rng.uniform(size=(b,)).astype(F32))
    losses = []
    for i in range(8):
        params, opt, loss, _ = step_fn(
            params, opt, jnp.float32(i + 1), jnp.float32(1e-2), x0, noise, t
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_sampler_step_moves_toward_velocity(diff_cfg):
    params = M.diff_init(diff_cfg, jnp.int32(2))
    rng = np.random.default_rng(5)
    b = 2
    x = jnp.asarray(rng.normal(size=(b, diff_cfg.frames, diff_cfg.latent_dim)).astype(F32))
    t = jnp.full((b,), 0.9, jnp.float32)
    dt = jnp.full((b,), 0.1, jnp.float32)
    cfg = preset("f32", block_q=16, block_k=16)
    v = M.diff_velocity(params, x, t, diff_cfg, cfg, "jnp")
    x2 = M.diff_sample_step(params, x, t, dt, diff_cfg, cfg, "jnp")
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x - 0.1 * v), atol=1e-6)


# ---------------------------------------------------------------------------
# Serve-path graphs == full forward
# ---------------------------------------------------------------------------


def test_serve_path_matches_full_forward(lm_cfg, lm_params):
    """Running the per-layer decode graphs token by token with exact (f32)
    attention must reproduce lm_logits (the serve decomposition is lossless
    up to attention precision, which Rust then intentionally quantizes)."""
    rng = np.random.default_rng(6)
    n = 8
    tokens = jnp.asarray(rng.integers(0, 256, (1, n)), jnp.int32)
    cfg = preset("f32", causal=True, block_q=32, block_k=32)
    want = M.lm_logits(lm_params, tokens, lm_cfg, cfg, "jnp")  # (1, n, V)

    hd = lm_cfg.head_dim
    h_layers_k = [[] for _ in range(lm_cfg.n_layers)]
    h_layers_v = [[] for _ in range(lm_cfg.n_layers)]
    got_last = None
    for pos in range(n):
        h = M.lm_embed_step(
            lm_params["tok_emb"], lm_params["pos_emb"], tokens[:, pos], jnp.asarray([pos])
        )
        for l in range(lm_cfg.n_layers):
            lw = {k: lm_params[k][l] for k in
                  ["ln1_w", "ln1_b", "wqkv", "bqkv", "wo", "bo", "ln2_w", "ln2_b",
                   "win", "bin", "wout", "bout"]}
            q, k_, v_ = M.lm_layer_pre(h, lw["ln1_w"], lw["ln1_b"], lw["wqkv"], lw["bqkv"])
            h_layers_k[l].append(k_)
            h_layers_v[l].append(v_)
            ks = jnp.stack(h_layers_k[l], axis=1)  # (1, t, D)
            vs = jnp.stack(h_layers_v[l], axis=1)
            outs = []
            for head in range(lm_cfg.n_heads):
                qh = q[:, head * hd:(head + 1) * hd]  # (1, hd)
                kh = ks[:, :, head * hd:(head + 1) * hd][0]  # (t, hd)
                vh = vs[:, :, head * hd:(head + 1) * hd][0]
                s = (qh @ kh.T) / jnp.sqrt(jnp.float32(hd))
                p = jax.nn.softmax(s, axis=-1)
                outs.append(p @ vh)
            attn = jnp.concatenate(outs, axis=-1)
            h = M.lm_layer_post(h, attn, lw["wo"], lw["bo"], lw["ln2_w"], lw["ln2_b"],
                                lw["win"], lw["bin"], lw["wout"], lw["bout"])
        got_last = M.lm_head_step(h, lm_params["lnf_w"], lm_params["lnf_b"], lm_params["head"])
        np.testing.assert_allclose(
            np.asarray(got_last[0]), np.asarray(want[0, pos]), atol=2e-4
        )
